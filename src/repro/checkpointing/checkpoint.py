"""Pytree checkpointing: npz blobs + structure metadata; atomic writes."""
from __future__ import annotations

import json
import os
import tempfile

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree) -> str:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    tmp = tempfile.NamedTemporaryFile(dir=path, delete=False, suffix=".tmp")
    np.savez(tmp, treedef=json.dumps(str(treedef)),
             **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    tmp.close()
    os.replace(tmp.name, fname)
    return fname


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def restore(path: str, step: int, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    leaves, treedef = _flatten(template)
    new = [data[f"leaf_{i}"] for i in range(len(leaves))]
    for old, loaded in zip(leaves, new):
        assert np.shape(old) == loaded.shape, (np.shape(old), loaded.shape)
    return jax.tree.unflatten(treedef, new)
