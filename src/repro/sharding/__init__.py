from repro.sharding.specs import (
    activation_sharding,
    activation_spec,
    infer_pytree_specs,
    maybe_constrain,
    set_activation_spec,
    set_mesh,
    spec_for_shape,
)

__all__ = [
    "activation_sharding",
    "activation_spec",
    "infer_pytree_specs",
    "maybe_constrain",
    "set_activation_spec",
    "set_mesh",
    "spec_for_shape",
]
