"""Sharding spec inference for parameters / optimizer states / caches.

Rule-based GSPMD spec chooser: for each array leaf,
  - an explicit leading *client* axis (federated ``pod_silo`` placement) is
    sharded over "pod" when present in the mesh;
  - the last dimension divisible by the "model" axis is tensor-sharded;
  - the largest remaining dimension divisible by the "data" axis is
    FSDP-sharded;
  - everything else replicated.

Activations use Megatron-style sequence parallelism between blocks: the
residual stream [B, T, D] is constrained to P(dp, "model", None) (T sharded
over the tensor axis) via the ``set_activation_spec`` context hook that
``repro.models.model.forward`` consults — this is what bounds per-device
activation memory for 4k-train / 32k-prefill on 100-layer stacks.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def set_activation_spec(spec: Optional[P]):
    _ctx.spec = spec


def activation_spec() -> Optional[P]:
    return getattr(_ctx, "spec", None)


@contextmanager
def activation_sharding(spec: Optional[P]):
    old = activation_spec()
    set_activation_spec(spec)
    try:
        yield
    finally:
        set_activation_spec(old)


def maybe_constrain(x):
    """Apply the context activation spec to a [B, T, D] residual, when set and
    when the dims divide the mesh axes."""
    spec = activation_spec()
    if spec is None:
        return x
    try:
        mesh = _ctx.mesh
    except AttributeError:
        return x
    if mesh is None or x.ndim != len(spec):
        return x
    ok_spec = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            ok_spec.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        ok_spec.append(ax if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*ok_spec)))


def set_mesh(mesh: Optional[Mesh]):
    _ctx.mesh = mesh


def leading_axis_sharding(mesh: Mesh, axis: str = "batch") -> NamedSharding:
    """Shard dim 0 over ``axis``, replicate the rest — the placement of every
    [B]-leading leaf in the sweep engine's sharded batch (trailing dims are
    left unspecified, so one spec serves leaves of any rank >= 1)."""
    return NamedSharding(mesh, P(axis))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """One full copy per device (the sweep engine's ``shared`` dataset)."""
    return NamedSharding(mesh, P())


def _axis_ok(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.axis_names and dim % mesh.shape[axis] == 0


def spec_for_shape(shape, mesh: Mesh, *, client_axis: bool = False,
                   model_axis="model", data_axis="data", pod_axis="pod") -> P:
    """Choose a PartitionSpec for one array shape.

    A dim that does not divide the model axis is still sharded when it is at
    least as large as the axis (GSPMD pads the ragged last shard): without
    the fallback, LM leaves with odd dims — a 49152x577 tied embedding, a
    head projection against a non-power-of-two vocab — would silently
    replicate on every device, which is exactly the memory blow-up the model
    axis exists to avoid. Dims smaller than the axis replicate (a shard per
    device would be mostly padding). NOTE: uneven specs are consumed via
    ``with_sharding_constraint``, which accepts them (GSPMD pads the ragged
    shard); ``jax.device_put`` and jit in/out shardings reject non-divisible
    dims, so commit uneven leaves through a jitted constraint instead.
    """
    spec = [None] * len(shape)
    start = 0
    if client_axis and len(shape) >= 1:
        start = 1  # client axis is never tensor/fsdp-sharded
        if pod_axis in mesh.axis_names and shape[0] % mesh.shape[pod_axis] == 0:
            spec[0] = pod_axis
    body = list(range(start, len(shape)))
    if not body:
        return P(*spec)
    # tensor axis: last divisible dim (prefer the true last)
    for d in reversed(body):
        if _axis_ok(shape[d], mesh, model_axis) and shape[d] >= mesh.shape[model_axis]:
            spec[d] = model_axis
            body.remove(d)
            break
    else:
        # pad-or-replicate fallback: no dim divides the model axis — shard
        # the largest dim that can still fill every device (>= axis size)
        if model_axis in mesh.axis_names:
            n = mesh.shape[model_axis]
            cands = [d for d in body if shape[d] >= n]
            if cands:
                d = max(cands, key=lambda d: shape[d])
                spec[d] = model_axis
                body.remove(d)
    # fsdp axis: largest remaining divisible dim
    body.sort(key=lambda d: -shape[d])
    for d in body:
        if _axis_ok(shape[d], mesh, data_axis) and shape[d] >= mesh.shape[data_axis] * 2:
            spec[d] = data_axis
            break
    return P(*spec)


def _moe_expert_spec(shape, mesh: Mesh, *, client_axis: bool) -> Optional[P]:
    """Expert-parallel: shard the expert dim of [E, d, f] weights over
    "model" (each shard owns E/model experts; token routing becomes the
    all-to-all the paper-era MoE systems use)."""
    off = 1 if client_axis else 0
    if len(shape) != 3 + off:
        return None
    e = shape[off]
    if not _axis_ok(e, mesh, "model"):
        return None
    spec = ([("pod" if "pod" in mesh.axis_names and shape[0] % mesh.shape["pod"] == 0
              else None)] if client_axis else [])
    spec += ["model", None, None]
    # NOTE (§Perf H4b): declaring "data" on the f dim instead of d compiles to
    # a byte-identical program — GSPMD re-lays out expert weights to its own
    # preference either way, so the choice below is cosmetic.
    if _axis_ok(shape[off + 1], mesh, "data"):
        spec[off + 1] = "data"
    elif _axis_ok(shape[off + 2], mesh, "data"):
        spec[off + 2] = "data"
    return P(*spec)


def infer_pytree_specs(tree, mesh: Mesh, *, client_axis: bool = False):
    """Map ``spec_for_shape`` over a pytree of arrays / ShapeDtypeStructs.
    MoE expert weights (path contains 'moe', rank-3 [E, d, f]) get
    expert-parallel sharding."""

    def leaf_spec(path, x):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if "moe" in names:
            sp = _moe_expert_spec(x.shape, mesh, client_axis=client_axis)
            if sp is not None:
                return NamedSharding(mesh, sp)
        return NamedSharding(mesh, spec_for_shape(x.shape, mesh,
                                                  client_axis=client_axis))

    return jax.tree_util.tree_map_with_path(leaf_spec, tree)
