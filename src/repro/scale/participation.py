"""On-device per-round cohort subsampling.

Cross-device servers never talk to all m clients in a round: a cohort of
C ≪ m candidates is drawn, and only those face the link process. The
composition preserves ``core/federated.py``'s mask semantics — the link
is still sampled over the full ``[m]`` population (its state, Markov
chains included, advances identically whether or not a cohort is drawn),
and the cohort's arrival mask is the *gather* ``active[cohort]`` — so a
client participates iff it is sampled AND its uplink is up, and the
per-round client-side compute/memory is O(C) not O(m).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_cohort(key, m: int, size: int) -> jnp.ndarray:
    """Uniform without-replacement cohort: ``[size]`` unique int32 client
    indices in [0, m). ``size`` is static (shapes depend on it)."""
    if not 1 <= size <= m:
        raise ValueError(f"cohort size {size} must be in [1, m={m}]")
    return jax.random.choice(key, m, (size,), replace=False).astype(jnp.int32)


def cohort_arrivals(cohort, active_m, p_t_m):
    """Gather the full-population link draw down to the cohort: the ``[C]``
    arrival mask (sampled AND link up) and the matching ``[C]`` link
    probabilities for importance-weighted members."""
    return active_m[cohort], p_t_m[cohort]


def scatter_mask(cohort, values, m: int) -> jnp.ndarray:
    """Scatter a ``[C]`` bool cohort mask into a dense ``[m]`` mask (rows
    outside the cohort are False) — for bookkeeping that stays ``[m]``."""
    return jnp.zeros((m,), bool).at[cohort].set(values)
