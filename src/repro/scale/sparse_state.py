"""Gather/scatter sparse updates for per-client ``AlgoState`` leaves.

The stateful aggregation rules keep ``[m, ...]`` per-client leaves —
FedAU's gap stats, MIFA's update memory, F3AST's availability EMAs,
FedPBC-M's momentum. At m=50k a dense elementwise update over those
leaves every round is exactly the O(m) work cohort subsampling exists to
avoid, and for MIFA the ``[m, n_params]`` memory write would dominate.
Here each rule gets a *cohort branch*: per-client state is read via
``leaf[cohort]`` gathers and written via ``leaf.at[cohort].set`` scatters,
so only the C sampled rows are touched per round and no dense
``[m, n_params]`` *update* tensor materializes (MIFA's memory itself is
inherently ``[m, n_params]`` storage; its per-round write is O(C·n) and
its read is the running mean over rows).

Semantics vs the dense branches: identical update rules applied to the
cohort's rows, with population normalizations taken over the cohort
(C clients drew a round; the delta-weighted members average over those C
candidates, and FedAU's gap clocks tick in cohort appearances — the
natural unit when a client's state is only observable when sampled).
Every branch has signature
``(algo_state, server, x_star_c, cohort, c_active, c_p, t) ->
(algo_state', server')`` with ``x_star_c``/``c_active``/``c_p`` already
gathered to ``[C, ...]``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core.algorithms import (
    AlgorithmSpec,
    _bmask,
    masked_mean,
    weighted_sum,
)

Pytree = Any


def _delta(x_star, server):
    return jax.tree.map(
        lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32),
        x_star, server)


def _apply(server, upd):
    return jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)


def _cohort_fedau(spec: AlgorithmSpec) -> Callable:
    K = spec.fedau_K

    def branch(algo, server, x_star, cohort, c_active, c_p, t):
        C = c_active.shape[0]
        gap_c = jnp.minimum(algo.gap[cohort] + 1.0, float(K))
        sum_c = algo.sum_gaps[cohort] + jnp.where(c_active, gap_c, 0.0)
        n_c = algo.n_gaps[cohort] + c_active.astype(jnp.float32)
        mean_gap = jnp.where(n_c > 0, sum_c / jnp.maximum(n_c, 1.0), 1.0)
        w = c_active.astype(jnp.float32) * mean_gap / C
        new_server = _apply(server, weighted_sum(_delta(x_star, server), w))
        new_algo = dataclasses.replace(
            algo,
            gap=algo.gap.at[cohort].set(jnp.where(c_active, 0.0, gap_c)),
            sum_gaps=algo.sum_gaps.at[cohort].set(sum_c),
            n_gaps=algo.n_gaps.at[cohort].set(n_c))
        return new_algo, new_server

    return branch


def _cohort_mifa(spec: AlgorithmSpec) -> Callable:
    def branch(algo, server, x_star, cohort, c_active, c_p, t):
        delta = _delta(x_star, server)
        # O(C·n) scatter: only arrived cohort rows of the memory change
        mem = jax.tree.map(
            lambda old, new: old.at[cohort].set(
                jnp.where(_bmask(c_active, new) > 0, new.astype(old.dtype),
                          old[cohort])),
            algo.mem, delta)
        upd = jax.tree.map(lambda g: g.mean(0), mem)
        return dataclasses.replace(algo, mem=mem), _apply(server, upd)

    return branch


def _cohort_f3ast(spec: AlgorithmSpec) -> Callable:
    beta, cap = spec.f3ast_beta, spec.f3ast_cap

    def branch(algo, server, x_star, cohort, c_active, c_p, t):
        lam_c = (1.0 - beta) * algo.lam[cohort] \
            + beta * c_active.astype(jnp.float32)
        # availability-balanced pick within the cohort: the `cap` arrived
        # clients with the smallest EMA
        score = jnp.where(c_active, lam_c, jnp.inf)
        rank = jnp.argsort(jnp.argsort(score))
        selected = c_active & (rank < cap)
        any_sel = selected.any()
        agg = masked_mean(x_star, selected)
        new_server = jax.tree.map(
            lambda a, s: jnp.where(any_sel, a, s), agg, server)
        new_algo = dataclasses.replace(
            algo, lam=algo.lam.at[cohort].set(lam_c))
        return new_algo, new_server

    return branch


def _cohort_fedpbc_m(spec: AlgorithmSpec) -> Callable:
    beta = spec.fedpbc_m_beta

    def branch(algo, server, x_star, cohort, c_active, c_p, t):
        any_active = c_active.any()
        agg = masked_mean(x_star, c_active)
        step = jax.tree.map(
            lambda a, s: jnp.where(any_active, a.astype(jnp.float32)
                                   - s.astype(jnp.float32), 0.0), agg, server)
        mom = jax.tree.map(lambda m_, g: beta * m_[0] + g, algo.mom, step)
        new_server = jax.tree.map(
            lambda s, m_: (s.astype(jnp.float32) + m_).astype(s.dtype),
            server, mom)
        new_algo = dataclasses.replace(
            algo, mom=jax.tree.map(lambda x: x[None], mom))
        return new_algo, new_server

    return branch


_COHORT_DEFS: Dict[str, Callable[[AlgorithmSpec], Callable]] = {
    "fedau": _cohort_fedau,
    "mifa": _cohort_mifa,
    "f3ast": _cohort_f3ast,
    "fedpbc_m": _cohort_fedpbc_m,
}

COHORT_STATEFUL = frozenset(_COHORT_DEFS)


def cohort_branch(name: str, spec: AlgorithmSpec) -> Callable:
    """The sparse cohort aggregate for a stateful rule. The fusable
    (empty-state) family does not appear here: its cohort path runs
    through the buffer engine (``repro.scale.buffer``), SYNC knobs
    included."""
    if name not in _COHORT_DEFS:
        raise ValueError(
            f"no sparse cohort branch for {name!r} (stateful rules: "
            f"{sorted(_COHORT_DEFS)}; the empty-state family aggregates "
            f"through the buffer engine)")
    return _COHORT_DEFS[name](spec)
