"""Cross-device scale subsystem: cohort subsampling + buffered semi-async
aggregation + sparse per-client state. See the module docstrings for the
three pieces; ``core/federated.py`` threads them through the round engine
(``make_round_fn(strategy=..., cohort_size=...)``) and
``experiments/grid.py`` exposes them as sweep axes
(``SweepSpec.strategies`` / ``SweepSpec.cohort_size``)."""
from repro.scale.buffer import (
    BUFFER_METRIC_KEYS,
    STRATEGY_KNOB_FIELDS,
    SYNC,
    BufferState,
    Strategy,
    buffered_aggregate,
    init_buffer_state,
    knobs_of,
    strategy_knob_columns,
)
from repro.scale.participation import (
    cohort_arrivals,
    sample_cohort,
    scatter_mask,
)
from repro.scale.sparse_state import COHORT_STATEFUL, cohort_branch

__all__ = [
    "BUFFER_METRIC_KEYS",
    "STRATEGY_KNOB_FIELDS",
    "SYNC",
    "BufferState",
    "Strategy",
    "buffered_aggregate",
    "init_buffer_state",
    "knobs_of",
    "strategy_knob_columns",
    "cohort_arrivals",
    "sample_cohort",
    "scatter_mask",
    "COHORT_STATEFUL",
    "cohort_branch",
]
