"""Buffered semi-async aggregation engine.

Cross-device FL servers do not wait for the whole active set: arriving
client updates are folded into a running buffer and an aggregation step
*commits* when the buffer fills or a deadline passes — the
``Strategy(wait_for_full, buffer_size, ms_to_wait)`` shape of
afl-aggregation-bench (SNIPPETS.md), with the wall-clock deadline recast
in *rounds* (the engine's native clock). Between commits the server
model is frozen, so every buffered contributor trained from its own
model — FedPBC's implicit gossiping happens among them by construction
— and on commit the postponed broadcast goes to exactly the clients
whose updates entered the committed buffer.

The fold is exact for the whole fusable (empty-state) family: each
member's server rule is either a masked mean (``OP_MEAN``) or a
weighted-delta step (``OP_ALL`` / ``OP_KNOWN_P``), and both are sums
over contributions — so folding per-round partial sums into
``(acc, weight)`` and dividing/adding once at commit reproduces the
synchronous update. In the degenerate configuration (commit every
round: ``deadline_rounds=1`` without ``wait_for_full``, or
``wait_for_full`` with a buffer the round always fills) the committed
expression is term-for-term the synchronous ``masked_mean`` /
``weighted_sum`` trace, which is what the bit-for-bit pin in
``tests/test_staleness.py`` holds the engine to.

Staleness: each buffered contribution ages one round per round it waits;
``age_sum``/``count`` track the buffer's total age so the per-commit mean
staleness is exact. ``staleness_discount`` multiplies the standing buffer
(numerator AND weight) by ``1 - discount`` per round, down-weighting stale
contributions without biasing the mean.

Every strategy knob is a *traced* per-trajectory input in the sweep
engine (``strategy_knob_columns``), so buffered-vs-sync — or a whole
grid of buffer sizes and deadlines — is one more batched dimension of a
single compiled program.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import _bmask
from repro.kernels.masked_agg import OP_ALL, OP_KNOWN_P, OP_MEAN

Pytree = Any


@dataclass(frozen=True)
class Strategy:
    """One buffered-aggregation policy (a sweep-axis value).

    ``wait_for_full``: commit ONLY when ``buffer_size`` contributions have
    arrived (the deadline is ignored). Otherwise commit when the buffer
    fills OR ``deadline_rounds`` rounds have passed since the last commit.
    ``staleness_discount`` in [0, 1): per-round decay applied to the
    standing buffer (0 = pure partial sums, the exact fold).
    """

    name: str
    wait_for_full: bool = False
    buffer_size: int = 1
    deadline_rounds: int = 1
    staleness_discount: float = 0.0

    @property
    def is_sync(self) -> bool:
        """Whether this policy commits every round regardless of arrivals —
        the degenerate configuration equal to the synchronous engine."""
        return (not self.wait_for_full) and self.deadline_rounds == 1


SYNC = Strategy("sync")

# Traced knob columns, in batch-layout order. dtypes: bool/int32/int32/float32.
STRATEGY_KNOB_FIELDS = ("wait_for_full", "buffer_size", "deadline_rounds",
                        "staleness_discount")

# Per-round metrics every buffered round emits (callers extend metric_keys).
BUFFER_METRIC_KEYS = ("commit", "buffer_fill", "commit_staleness")


def knobs_of(strategy: Union[Strategy, Mapping[str, Any], None]) -> Dict[str, Any]:
    """Normalize a strategy into its knob dict: a ``Strategy`` gives python
    scalars (static branches in the trace), a mapping passes through (the
    sweep engine's traced per-trajectory columns), None means SYNC."""
    if strategy is None:
        strategy = SYNC
    if isinstance(strategy, Strategy):
        return {"wait_for_full": bool(strategy.wait_for_full),
                "buffer_size": int(strategy.buffer_size),
                "deadline_rounds": int(strategy.deadline_rounds),
                "staleness_discount": float(strategy.staleness_discount)}
    missing = [k for k in STRATEGY_KNOB_FIELDS if k not in strategy]
    if missing:
        raise ValueError(f"strategy knob mapping is missing {missing}; "
                         f"expected keys {STRATEGY_KNOB_FIELDS}")
    return {k: strategy[k] for k in STRATEGY_KNOB_FIELDS}


def strategy_knob_columns(strategies: Sequence[Strategy],
                          block: int) -> Dict[str, jnp.ndarray]:
    """Batch-layout knob columns: each strategy's scalars repeated over its
    ``block`` trajectories, concatenated in strategy order — the traced
    inputs that make the strategy axis one more batched dimension."""
    cols = {
        "wait_for_full": np.repeat(
            np.asarray([s.wait_for_full for s in strategies], np.bool_), block),
        "buffer_size": np.repeat(
            np.asarray([s.buffer_size for s in strategies], np.int32), block),
        "deadline_rounds": np.repeat(
            np.asarray([s.deadline_rounds for s in strategies], np.int32), block),
        "staleness_discount": np.repeat(
            np.asarray([s.staleness_discount for s in strategies], np.float32),
            block),
    }
    return {k: jnp.asarray(v) for k, v in cols.items()}


@dataclass
class BufferState:
    """The server's running buffer between commits.

    ``acc`` mirrors the server pytree in fp32 (partial numerator / delta
    sum); ``weight``/``count`` are the folded denominator and contribution
    count; ``since`` counts rounds since the last commit (the deadline
    clock); ``age_sum`` accumulates contribution ages for the staleness
    metric; ``in_buffer`` marks clients with an update in the standing
    buffer (the postponed-broadcast recipients); ``commits`` counts commits.
    """

    acc: Pytree
    weight: jnp.ndarray     # scalar f32
    count: jnp.ndarray      # scalar i32
    since: jnp.ndarray      # scalar i32
    age_sum: jnp.ndarray    # scalar f32
    in_buffer: jnp.ndarray  # [m] bool
    commits: jnp.ndarray    # scalar i32


jax.tree_util.register_dataclass(
    BufferState,
    data_fields=["acc", "weight", "count", "since", "age_sum", "in_buffer",
                 "commits"],
    meta_fields=[],
)


def init_buffer_state(server: Pytree, m: int) -> BufferState:
    return BufferState(
        acc=jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), server),
        weight=jnp.float32(0.0),
        count=jnp.int32(0),
        since=jnp.int32(0),
        age_sum=jnp.float32(0.0),
        in_buffer=jnp.zeros((m,), bool),
        commits=jnp.int32(0),
    )


def _sel(pred, a, b):
    """Select that stays a python branch for static (bool) predicates."""
    if isinstance(pred, (bool, np.bool_)):
        return a if pred else b
    return jnp.where(pred, a, b)


def buffered_aggregate(buf: BufferState, server: Pytree, x_star: Pytree,
                       active, p_t, knobs: Mapping[str, Any], *, op,
                       m_total: int, in_buffer_new) -> tuple:
    """Fold one round of arrivals into the buffer; commit if due.

    ``x_star``: the round's trained client params, leading axis matching
    ``active`` (the full ``[m]`` population or a gathered ``[C]`` cohort).
    ``op``: the member's fused opcode (``FUSED_OPS[name]``) — a python int
    for a static member or a traced scalar for the batched family axis.
    ``m_total``: the population the delta-weighted members normalize by
    (m dense, C in cohort mode). ``in_buffer_new``: the updated ``[m]``
    membership mask (caller scatters cohort arrivals into it).

    Returns ``(new_buffer, new_server, commit, metrics)`` with ``metrics``
    keyed by ``BUFFER_METRIC_KEYS``.
    """
    f32 = jnp.float32
    static_op = isinstance(op, (int, np.integer))
    w_mean = active.astype(f32)
    if static_op:
        is_mean = int(op) == OP_MEAN
        if int(op) == OP_MEAN:
            w = w_mean
        elif int(op) == OP_ALL:
            w = w_mean / m_total
        else:
            w = w_mean / jnp.maximum(p_t, 1e-3) / m_total
    else:
        is_mean = op == OP_MEAN
        w = jnp.where(is_mean, w_mean,
                      jnp.where(op == OP_ALL, w_mean / m_total,
                                w_mean / jnp.maximum(p_t, 1e-3) / m_total))

    decay = 1.0 - knobs["staleness_discount"]

    # Fold this round's arrivals. mean members accumulate raw params
    # (the masked_mean numerator), delta members accumulate weighted
    # deltas vs the FROZEN server — between commits the server does not
    # move, so the fold is the synchronous sum taken in installments.
    def leaf_contrib(xs, s):
        xf = xs.astype(f32)
        if static_op:
            d = xf if is_mean else xf - s[None].astype(f32)
        else:
            d = jnp.where(is_mean, xf, xf - s[None].astype(f32))
        return (d * _bmask(w, d)).sum(0)

    contrib = jax.tree.map(leaf_contrib, x_star, server)
    # decay * 0 + contrib == contrib exactly (the standing buffer is +0.0
    # after init/commit), so the commit-every-round path stays bitwise.
    acc = jax.tree.map(lambda a, c: decay * a + c, buf.acc, contrib)
    weight = decay * buf.weight + w.sum()
    n_new = active.sum().astype(jnp.int32)
    count = buf.count + n_new
    since = buf.since + 1
    # everything already buffered ages one round before the new arrivals land
    age_sum = buf.age_sum + buf.count.astype(f32)

    full = count >= knobs["buffer_size"]
    due = since >= knobs["deadline_rounds"]
    commit = _sel(knobs["wait_for_full"], full, full | due)

    # Commit expressions mirror the synchronous branches term for term:
    # mean members divide by max(weight, 1) and keep the server on an empty
    # buffer; delta members add the folded update.
    denom = jnp.maximum(weight, 1.0)
    nonempty = weight > 0.0

    def leaf_server(a, s):
        mean_srv = jnp.where(nonempty, (a / denom).astype(s.dtype), s)
        delta_srv = s + a.astype(s.dtype)
        committed = _sel(is_mean, mean_srv, delta_srv)
        return jnp.where(commit, committed, s)

    new_server = jax.tree.map(leaf_server, acc, server)

    mean_age = age_sum / jnp.maximum(count.astype(f32), 1.0)
    new_buf = BufferState(
        acc=jax.tree.map(lambda a: jnp.where(commit, 0.0, a), acc),
        weight=jnp.where(commit, 0.0, weight),
        count=jnp.where(commit, 0, count),
        since=jnp.where(commit, 0, since),
        age_sum=jnp.where(commit, 0.0, age_sum),
        in_buffer=jnp.where(commit, jnp.zeros_like(in_buffer_new),
                            in_buffer_new),
        commits=buf.commits + commit.astype(jnp.int32),
    )
    metrics = {
        "commit": commit.astype(f32),
        "buffer_fill": count.astype(f32),
        "commit_staleness": jnp.where(commit, mean_age, 0.0),
    }
    return new_buf, new_server, commit, metrics
