"""Pallas TPU kernel: flash attention (causal / sliding-window / softcap).

Online-softmax with explicit VMEM tiling: grid (B*H, Tq/bq, Tk/bk), the KV
axis innermost so the running (m, l, acc) triple lives in VMEM scratch across
KV steps and the output tile is written once on the last step. Block shapes
are MXU-aligned (multiples of 128 on the matmul dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale, causal, window, softcap, bq, bk, n_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale        # [bq, d]
    k = k_ref[0].astype(jnp.float32)                # [bk, d]
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    allow = jnp.ones((bq, bk), bool)
    if causal:
        allow &= q_pos >= k_pos
    if window:
        allow &= q_pos - k_pos < window
    s = jnp.where(allow, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "logit_softcap", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    bq=128, bk=128, interpret=True):
    """q, k, v: [B, H, T, D] (same head count; GQA handled by the wrapper)."""
    b, h, t, d = q.shape
    bq = min(bq, t)
    bk = min(bk, t)
    assert t % bq == 0 and t % bk == 0, (t, bq, bk)
    n_k = t // bk
    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, causal=causal, window=window,
        softcap=logit_softcap, bq=bq, bk=bk, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, t // bq, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, t, d)
