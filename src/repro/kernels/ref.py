"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_agg_ref(x, mask, prev=None):
    """FedPBC server aggregation (Alg. 1 line 11): mean over active clients.

    x: [m, n] stacked client parameters; mask: [m] bool/0-1.
    out: [n] = sum_i mask_i x_i / max(1, sum mask).

    ``prev`` ([n], optional) is the previous server params: when given, an
    empty active set returns ``prev`` (the engine's ``any_active`` guard)
    instead of the zero vector.
    """
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    out = (x.astype(jnp.float32) * mask[:, None]).sum(0) / denom
    if prev is None:
        return out
    return jnp.where(mask.sum() > 0, out, prev.astype(jnp.float32))


def fused_masked_agg_ref(x, mask, op, prev, p):
    """Pure-jnp oracle for the fused family-aggregation kernel — identical
    math (fp32 accumulation, same weight expressions and select) to
    ``repro.kernels.masked_agg._fused_kernel``; also the dispatch layer's
    always-available XLA fallback path.

    Single trajectory: x [m, n], mask [m], op scalar, prev [n], p [m];
    batched: a leading [B] axis on every argument. Returns fp32 [n] / [B, n].
    """
    if x.ndim == 3:
        return jax.vmap(fused_masked_agg_ref)(x, mask, op, prev, p)
    from repro.kernels.masked_agg import OP_ALL, OP_MEAN

    m = x.shape[0]
    xf = x.astype(jnp.float32)
    mk = mask.astype(jnp.float32)
    prev = prev.astype(jnp.float32)
    n_active = mk.sum()
    mean_agg = (xf * mk[:, None]).sum(0) / jnp.maximum(n_active, 1.0)
    mean_out = jnp.where(n_active > 0, mean_agg, prev)
    delta = xf - prev[None]
    all_out = prev + (delta * (mk / m)[:, None]).sum(0)
    w_kp = mk / jnp.maximum(p.astype(jnp.float32), 1e-3) / m
    kp_out = prev + (delta * w_kp[:, None]).sum(0)
    return jnp.where(op == OP_MEAN, mean_out,
                     jnp.where(op == OP_ALL, all_out, kp_out))


def flash_attention_ref(q, k, v, *, causal=True, window=0, logit_softcap=0.0):
    """Naive softmax attention. q,k,v: [B, H, T, D] (same head count)."""
    b, h, t, d = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qp = jnp.arange(t)
    allow = jnp.ones((t, t), bool)
    if causal:
        allow &= qp[:, None] >= qp[None, :]
    if window:
        allow &= qp[:, None] - qp[None, :] < window
    s = jnp.where(allow, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rwkv6_chunk_ref(r, k, v, w, u, s0):
    """RWKV6 recurrence, step-by-step scan (the semantic ground truth).

    r,k,v,w: [B, H, T, D]; u: [H, D]; s0: [B, H, D, D] (S[k_dim, v_dim]).
    Returns (o [B,H,T,D], s_T).
      o_t = r_t @ S_{t-1} + (r_t . (u * k_t)) v_t
      S_t = diag(w_t) S_{t-1} + k_t^T v_t
    """
    b, h, t, d = r.shape

    def step(s, xs):
        rt, kt, vt, wt = xs  # [B,H,D]
        o = jnp.einsum("bhk,bhkv->bhv", rt, s)
        o = o + jnp.sum(rt * u[None] * kt, -1, keepdims=True) * vt
        s = wt[..., None] * s + kt[..., None] * vt[..., None, :]
        return s, o

    xs = tuple(x.transpose(2, 0, 1, 3) for x in (r, k, v, w))
    s_t, o = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return o.transpose(1, 2, 0, 3), s_t
