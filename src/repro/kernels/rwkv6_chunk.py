"""Pallas TPU kernel: RWKV6 chunked WKV recurrence.

One grid cell per (batch*head); the kernel walks the sequence in chunks of
``chunk`` with the [D, D] state held in VMEM scratch across the fori_loop.
Intra-chunk contributions use the decay-weighted lower-triangular matmul (the
chunked-WKV form), so each chunk is two MXU matmuls + elementwise decay math
instead of ``chunk`` sequential rank-1 updates.

Block layout: r/k/v/w arrive as [T, D] VMEM blocks per (b, h); D = head_dim
(64/128) and chunk=64 keep every operand MXU-aligned and the working set
(4 x T x D fp32 + D^2 state) within VMEM for T <= 8k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_ref, state, *,
            chunk, n_chunks):
    state[...] = s0_ref[0].astype(jnp.float32)     # [D, D]
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)
    u = u_ref[0].astype(jnp.float32)               # [1, D] -> broadcast

    def body(c, _):
        sl = pl.dslice(c * chunk, chunk)
        rb = r_ref[0, sl, :].astype(jnp.float32)   # [C, D]
        kb = k_ref[0, sl, :].astype(jnp.float32)
        vb = v_ref[0, sl, :].astype(jnp.float32)
        wb = w_ref[0, sl, :].astype(jnp.float32)
        logw = jnp.log(jnp.maximum(wb, 1e-12))
        q_inc = jnp.cumsum(logw, axis=0)
        q_exc = q_inc - logw
        r_dec = rb * jnp.exp(q_exc)
        k_dec = kb * jnp.exp(-q_inc)
        o = jax.lax.dot(r_dec, state[...])                       # inter-chunk
        scores = jax.lax.dot_general(
            r_dec, k_dec, (((1,), (1,)), ((), ()))) * tri        # intra
        o = o + jax.lax.dot(scores, vb)
        cur = jnp.sum(rb * u * kb, axis=-1, keepdims=True)       # bonus
        o = o + cur * vb
        total = q_inc[-1:, :]                                    # [1, D]
        k_tail = kb * jnp.exp(total - q_inc)
        state[...] = (jnp.exp(total)[0][:, None] * state[...]
                      + jax.lax.dot_general(k_tail, vb, (((0,), (0,)), ((), ()))))
        o_ref[0, sl, :] = o.astype(o_ref.dtype)
        return ()

    jax.lax.fori_loop(0, n_chunks, lambda c, _: body(c, _), ())
    s_ref[0] = state[...].astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_chunk(r, k, v, w, u, s0, *, chunk: int = 64, interpret: bool = True):
    """r,k,v,w: [B, H, T, D]; u: [H, D]; s0: [B, H, D, D] fp32.

    Returns (o [B,H,T,D] fp32, s_T [B,H,D,D] fp32).
    """
    b, h, t, d = r.shape
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk
    rf = r.reshape(b * h, t, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    wf = w.reshape(b * h, t, d)
    uf = jnp.broadcast_to(u[None], (b, h, d)).reshape(b * h, 1, d)
    sf = s0.reshape(b * h, d, d).astype(jnp.float32)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    o, s_out = pl.pallas_call(
        kernel,
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, t, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, t, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, t, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, t, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, d, d), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, d), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, d, d), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, t, d), jnp.float32),
            jax.ShapeDtypeStruct((b * h, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, wf, uf, sf)
    return o.reshape(b, h, t, d), s_out.reshape(b, h, d, d)
