"""Backend-aware kernel dispatch for the fused aggregation hot path.

One resolution layer decides how the sweep engine's server aggregation
executes, so the same traced program runs everywhere:

- ``"compiled"`` — the Pallas kernel compiled for the accelerator
  (``interpret=False``); the default on TPU/GPU backends.
- ``"interpret"`` — the Pallas kernel in interpret mode: the kernel body is
  traced to plain XLA ops, so it runs (and is differentiable/shardable)
  anywhere; the default on CPU. On CPU this is bitwise identical to the
  engine's XLA path for fp32 leaves.
- ``"xla"`` — the pure-jnp reference (``fused_masked_agg_ref``), always
  available as a fallback independent of Pallas.

Overrides (highest precedence first): an explicit ``backend=`` argument,
the ``REPRO_KERNEL_BACKEND`` environment variable (``compiled`` /
``interpret`` / ``xla``), then the per-platform default above.

Whether the engine uses the kernel at all is a separate knob, threaded as
``use_kernel`` through ``AlgorithmSpec.aggregate`` -> ``make_round_fn`` ->
``make_batched_run_rounds`` -> ``SweepSpec``; ``None`` at any of those
levels defers to :func:`use_kernel_default` (the ``REPRO_USE_KERNEL``
environment variable, default off).

Tolerance contract vs the engine's XLA masked-mean path, per backend
(equality statements are between JITTED programs — the only way the hot
path runs either side; op-by-op eager dispatch may fuse multiply+reduce
differently at one-ulp level, see ``tests/test_kernels.py``):

==============  ============================================================
``interpret``   fp32 leaves: bitwise on CPU (a family sweep with
                ``use_kernel=True`` equals the XLA-path program per
                trajectory, pinned by ``tests/test_kernel_sweep.py``);
                bf16 leaves: the kernel accumulates in fp32 where the XLA
                path computes in bf16 — differences up to ~1e-2 * magnitude
                (bf16 epsilon).
``xla``         identical math to the kernel (fp32 accumulation): bitwise
                vs ``interpret`` on every platform.
``compiled``    allclose within 1e-6 (fp32) / 2e-2 (bf16): accelerator
                reduction order inside a block may differ from XLA's.
==============  ============================================================
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels.masked_agg import (
    OP_ALL,
    OP_KNOWN_P,
    OP_MEAN,
    fused_masked_agg,
)
from repro.kernels.ref import fused_masked_agg_ref

Pytree = Any

BACKENDS = ("compiled", "interpret", "xla")

_ENV_BACKEND = "REPRO_KERNEL_BACKEND"
_ENV_USE_KERNEL = "REPRO_USE_KERNEL"

# Aggregation opcode per algorithm name — the branch table the fused kernel
# folds into one select. Only these (the empty-state family) are fusable;
# stateful rules (fedau/mifa/f3ast/fedpbc_m) keep the lax.switch path.
FUSED_OPS = {
    "fedpbc": OP_MEAN,
    "fedavg": OP_MEAN,
    "fedavg_all": OP_ALL,
    "fedavg_known_p": OP_KNOWN_P,
}


def resolve_backend(backend: Optional[str] = None) -> str:
    """The kernel execution backend: explicit arg > ``REPRO_KERNEL_BACKEND``
    env var > platform default (compiled on tpu/gpu, interpret on cpu)."""
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND) or None
    if backend is None:
        backend = ("compiled" if jax.default_backend() in ("tpu", "gpu")
                   else "interpret")
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"available: {BACKENDS}")
    return backend


def use_kernel_default() -> bool:
    """The ambient ``use_kernel`` default: ``REPRO_USE_KERNEL`` env var
    (1/true/yes/on), else False (the engine's historical XLA path)."""
    return os.environ.get(_ENV_USE_KERNEL, "").strip().lower() in (
        "1", "true", "yes", "on")


def resolve_use_kernel(flag: Optional[bool] = None) -> bool:
    """Normalize a ``use_kernel`` knob: None defers to the env default."""
    return use_kernel_default() if flag is None else bool(flag)


def resolve_attention_backend(backend: Optional[str] = None) -> str:
    """The attention execution backend: explicit arg >
    ``REPRO_KERNEL_BACKEND`` env var > platform default.

    Unlike :func:`resolve_backend`, the CPU default is ``"xla"`` — the
    chunked online-softmax reference (``repro.models.attention``) IS the
    fast CPU path, while running the flash kernel's Pallas body in
    interpret mode is strictly slower there. ``"interpret"`` remains
    selectable (env var or arg) for kernel-parity audits.
    """
    if backend is None:
        backend = os.environ.get(_ENV_BACKEND) or None
    if backend is None:
        backend = ("compiled" if jax.default_backend() in ("tpu", "gpu")
                   else "xla")
    if backend not in BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; "
                         f"available: {BACKENDS}")
    return backend


def attention(q, k, v, *, kind="full", window=4096, logit_softcap=0.0,
              chunk=1024, q_offset=0, backend: Optional[str] = None):
    """Backend-dispatched causal attention in the model stack's
    ``[B, T, H, D]`` layout (``repro.models.attention.attention``'s
    signature; that entry routes here, closing the masked_agg-style audit
    for ``repro.kernels.flash_attention``).

    The Pallas kernel covers the training shapes: self-attention
    (``Tq == Tk``, ``q_offset == 0``), ``kind`` full or swa, and ``T``
    divisible by the kernel's block size. Everything else — block-local
    ("chunked") masks, decode/prefill offsets, ragged lengths — falls back
    to the pure-XLA reference, as does ``backend="xla"``. The kernel path
    repeats GQA kv-heads and transposes to the kernel's ``[B, H, T, D]``
    layout; tolerance vs the reference follows the module contract table
    (fp32: bitwise-adjacent allclose; the reference chunks over KV where
    the kernel blocks over both axes, so reduction order differs).
    """
    # lazy: models.attention routes its public entry through this function
    from repro.models import attention as ref

    backend = resolve_attention_backend(backend)
    b, tq, h, d = q.shape
    tk = k.shape[1]
    bq = min(128, tq)
    kernel_ok = (backend != "xla" and kind in ("full", "swa")
                 and q_offset == 0 and tq == tk and tq % bq == 0)
    if not kernel_ok:
        return ref.attention_ref(q, k, v, kind=kind, window=window,
                                 logit_softcap=logit_softcap, chunk=chunk,
                                 q_offset=q_offset)
    from repro.kernels.flash_attention import flash_attention

    n_rep = h // k.shape[2]
    kr = ref._repeat_kv(k, n_rep).transpose(0, 2, 1, 3)
    vr = ref._repeat_kv(v, n_rep).transpose(0, 2, 1, 3)
    out = flash_attention(q.transpose(0, 2, 1, 3), kr, vr, causal=True,
                          window=window if kind == "swa" else 0,
                          logit_softcap=logit_softcap,
                          interpret=(backend == "interpret"))
    return out.transpose(0, 2, 1, 3)


def fused_agg(x, mask, op, prev, p, *, block_n: int = 4096,
              backend: Optional[str] = None):
    """Backend-dispatched fused aggregation over one flattened leaf.

    Shapes as in ``fused_masked_agg``: ``[m, n]`` single-trajectory or
    ``[B, m, n]`` sweep layout (the 2-D form also lifts under ``vmap``).
    Returns fp32 new server params ``[n]`` / ``[B, n]``.
    """
    backend = resolve_backend(backend)
    if backend == "xla":
        return fused_masked_agg_ref(x, mask, op, prev, p)
    return fused_masked_agg(x, mask, op, prev, p, block_n=block_n,
                            interpret=(backend == "interpret"))


def fused_agg_pytree(x_star: Pytree, mask, op, server: Pytree, p, *,
                     block_n: int = 4096,
                     backend: Optional[str] = None) -> Pytree:
    """Per-leaf fused aggregation over an ``[m, ...]`` client-stacked pytree.

    Every leaf of ``x_star`` is flattened to ``[m, n]``, aggregated by one
    kernel call against the matching ``server`` leaf (flattened ``[n]``),
    and cast back to the leaf's dtype/shape. ``mask``/``p`` are shared
    across leaves ([m]); ``op`` is the per-trajectory branch opcode.
    Composable with ``vmap`` for the batched sweep layout.
    """
    backend = resolve_backend(backend)

    def leaf(xs, s):
        m = xs.shape[0]
        out = fused_agg(xs.reshape(m, -1), mask, op,
                        s.reshape(-1), p, block_n=block_n, backend=backend)
        return out.reshape(s.shape).astype(s.dtype)

    return jax.tree.map(leaf, x_star, server)
