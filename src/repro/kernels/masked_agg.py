"""Pallas kernels for the server-side aggregation hot spot (Alg. 1 line 11).

Two entry points share one tiled, memory-bound reduction structure:

- :func:`masked_agg` — the historical single-trajectory active-client mean
  over ``[m, n]`` stacked client params (kept for callers/benchmarks);
- :func:`fused_masked_agg` — the sweep-layout kernel: ``[B, m, n]`` stacked
  client params with a per-trajectory ``[B, m]`` active mask, a traced
  ``[B]`` branch opcode, the previous server params ``[B, n]`` and the
  connection probabilities ``[B, m]``. The state-compatible family's
  weighting branches (fedpbc / fedavg / fedavg_all / fedavg_known_p) are
  folded into ONE select inside the kernel body, so the whole family's
  server update is a single pass over HBM instead of a ``lax.switch`` that
  evaluates every branch under vmap.

Branch opcodes (see ``repro.kernels.dispatch``):

- ``OP_MEAN`` (0): guarded active-client mean — ``sum(mask*x)/max(|A|,1)``,
  falling back to ``prev`` when no client is active (the engine's
  ``any_active`` guard, folded into the kernel: a zero-active round
  preserves the previous server params instead of zeroing the model);
- ``OP_ALL`` (1): all-client delta mean — ``prev + sum(mask*(x-prev))/m``;
- ``OP_KNOWN_P`` (2): known-p importance weighting —
  ``prev + sum(mask*(x-prev) / max(p, 1e-3)) / m``.

All arithmetic is fp32 regardless of input dtype (fp32 accumulation for
bf16 inputs); outputs are fp32 and callers cast back per leaf. The kernel
tiles the (flattened) parameter dimension into VMEM-resident blocks and
keeps the whole (small) client axis per block, so each output element is
produced in one pass: grid ``(n/bn,)`` (2-D input) or ``(B, n/bn)`` (3-D).

``interpret=True`` (the CPU default via ``repro.kernels.dispatch``) traces
the body to plain XLA ops — on CPU the result is bitwise identical to the
engine's XLA masked-mean path for fp32 leaves; ``interpret=False`` compiles
the kernel on TPU/GPU (documented tolerance: see README "Kernels").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Branch opcodes of the fused kernel (must match repro.kernels.dispatch).
OP_MEAN = 0      # fedpbc / fedavg: guarded active-client mean
OP_ALL = 1       # fedavg_all: all-client delta mean
OP_KNOWN_P = 2   # fedavg_known_p: 1/(m * p_i) delta weighting


def _round_up(n: int, mult: int) -> int:
    return -(-n // mult) * mult


# ---------------------------------------------------------------------------
# Historical single-trajectory active-mean kernel
# ---------------------------------------------------------------------------


def _mean_kernel(mask_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)              # [m, bn]
    mask = mask_ref[...].astype(jnp.float32)        # [m, 1]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    o_ref[...] = (jnp.sum(x * mask, axis=0, keepdims=True) / denom)[0]


def _guarded_mean_kernel(mask_ref, prev_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)              # [m, bn]
    mask = mask_ref[...].astype(jnp.float32)        # [m, 1]
    prev = prev_ref[...].astype(jnp.float32)        # [1, bn]
    n_active = jnp.sum(mask)
    agg = jnp.sum(x * mask, axis=0, keepdims=True) / jnp.maximum(n_active, 1.0)
    o_ref[...] = jnp.where(n_active > 0, agg, prev)[0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def masked_agg(x, mask, prev=None, *, block_n: int = 4096,
               interpret: bool = True):
    """x: [m, n]; mask: [m]. Returns [n] fp32 (active-client mean).

    Zero-active semantics: with ``prev=None`` an empty active set yields the
    zero vector — exactly ``algorithms.masked_mean``'s fallback (callers
    guard with ``any_active``). Passing ``prev`` ([n]) folds that guard into
    the kernel: an empty active set returns ``prev`` (the previous server
    params) instead of silently zeroing the model, matching the engine-level
    ``jnp.where(any_active, masked_mean(...), server)`` semantics.
    """
    m, n = x.shape
    bn = min(block_n, _round_up(n, 128))
    pad = (-n) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    np_ = x.shape[1]
    mask2 = mask.astype(jnp.float32).reshape(m, 1)
    if prev is None:
        out = pl.pallas_call(
            _mean_kernel,
            grid=(np_ // bn,),
            in_specs=[
                pl.BlockSpec((m, 1), lambda i: (0, 0)),
                pl.BlockSpec((m, bn), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
            interpret=interpret,
        )(mask2, x)
        return out[:n]
    prev2 = jnp.pad(prev.astype(jnp.float32), (0, pad)).reshape(1, np_)
    out = pl.pallas_call(
        _guarded_mean_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(mask2, prev2, x)
    return out[:n]


# ---------------------------------------------------------------------------
# Fused family-aggregation kernel (the sweep hot path)
# ---------------------------------------------------------------------------


def _fused_kernel(op_ref, mask_ref, p_ref, prev_ref, x_ref, o_ref):
    """One [m, bn] block of one trajectory: every weighting variant computed
    from the single streamed read of ``x`` and selected by ``op``."""
    x = x_ref[...].astype(jnp.float32)              # [m, bn]
    mask = mask_ref[...].astype(jnp.float32)        # [m, 1]
    p = p_ref[...].astype(jnp.float32)              # [m, 1]
    prev = prev_ref[...].astype(jnp.float32)        # [1, bn]
    op = op_ref[0, 0]
    m = x.shape[0]
    # OP_MEAN: guarded active mean (the any_active guard folded in)
    n_active = jnp.sum(mask)
    mean_agg = jnp.sum(x * mask, axis=0, keepdims=True) \
        / jnp.maximum(n_active, 1.0)
    mean_out = jnp.where(n_active > 0, mean_agg, prev)
    # OP_ALL / OP_KNOWN_P: server + weighted delta sum (weights written in
    # the exact division order of the engine branches, for bitwise parity)
    delta = x - prev
    all_out = prev + jnp.sum(delta * (mask / m), axis=0, keepdims=True)
    w_kp = mask / jnp.maximum(p, 1e-3) / m
    kp_out = prev + jnp.sum(delta * w_kp, axis=0, keepdims=True)
    o_ref[...] = jnp.where(op == OP_MEAN, mean_out,
                           jnp.where(op == OP_ALL, all_out, kp_out))


def _fused_call_2d(x, mask, op, prev, p, bn: int, interpret: bool):
    m, np_ = x.shape
    assert np_ % bn == 0, (np_, bn)   # caller pads n up to a bn multiple
    return pl.pallas_call(
        _fused_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((m, bn), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, np_), jnp.float32),
        interpret=interpret,
    )(op, mask, p, prev, x)[0]


def _fused_batched_kernel(op_ref, mask_ref, p_ref, prev_ref, x_ref, o_ref):
    _fused_kernel(op_ref[0], mask_ref[0][..., None], p_ref[0][..., None],
                  prev_ref, x_ref[0], o_ref)


def _fused_call_3d(x, mask, op, prev, p, bn: int, interpret: bool):
    B, m, np_ = x.shape
    assert np_ % bn == 0, (np_, bn)   # caller pads n up to a bn multiple
    return pl.pallas_call(
        _fused_batched_kernel,
        grid=(B, np_ // bn),
        in_specs=[
            pl.BlockSpec((1, 1, 1), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, m), lambda b, i: (b, 0)),
            pl.BlockSpec((1, m), lambda b, i: (b, 0)),
            pl.BlockSpec((1, bn), lambda b, i: (b, i)),
            pl.BlockSpec((1, m, bn), lambda b, i: (b, 0, i)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, np_), jnp.float32),
        interpret=interpret,
    )(op, mask, p, prev, x)


def fused_masked_agg(x, mask, op, prev, p, *, block_n: int = 4096,
                     interpret: bool = True):
    """Fused family aggregation over stacked client params.

    Shapes — single trajectory: ``x [m, n]``, ``mask [m]``, ``op`` scalar,
    ``prev [n]``, ``p [m]``; sweep layout: ``x [B, m, n]``, ``mask [B, m]``,
    ``op [B]``, ``prev [B, n]``, ``p [B, m]``. Returns fp32 ``[n]`` /
    ``[B, n]``: the new server params under the branch each trajectory's
    ``op`` selects (see module docstring for the opcode table).

    The 2-D form also composes with ``jax.vmap`` (Pallas lifts the call to a
    batched grid), which is how the round engine reaches the sweep layout.
    """
    if x.ndim == 2:
        m, n = x.shape
        bn = min(block_n, _round_up(n, 128))
        pad = (-n) % bn
        xp = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
        prevp = jnp.pad(prev.astype(jnp.float32), (0, pad)).reshape(1, -1)
        out = _fused_call_2d(
            xp, mask.astype(jnp.float32).reshape(m, 1),
            jnp.asarray(op, jnp.int32).reshape(1, 1),
            prevp, p.astype(jnp.float32).reshape(m, 1), bn, interpret)
        return out[:n]
    B, m, n = x.shape
    bn = min(block_n, _round_up(n, 128))
    pad = (-n) % bn
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, pad))) if pad else x
    prevp = jnp.pad(prev.astype(jnp.float32), ((0, 0), (0, pad)))
    out = _fused_call_3d(
        xp, mask.astype(jnp.float32),
        jnp.asarray(op, jnp.int32).reshape(B, 1, 1),
        prevp, p.astype(jnp.float32), bn, interpret)
    return out[:, :n]
