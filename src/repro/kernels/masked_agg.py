"""Pallas TPU kernel: FedPBC masked client aggregation (Alg. 1 line 11).

The server-side hot spot: out = (1/|A|) sum_{i in A} x_i over the stacked
client-parameter axis. On TPU this is a memory-bound streaming reduction; the
kernel tiles the (flattened) parameter dimension into VMEM-resident blocks
and keeps the whole (small) client axis per block, so each output element is
produced in one pass over HBM.

Grid: (n // block_n,).  x block: [m, block_n] VMEM; mask: [m, 1] VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mask_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)              # [m, bn]
    mask = mask_ref[...].astype(jnp.float32)        # [m, 1]
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    o_ref[...] = (jnp.sum(x * mask, axis=0, keepdims=True) / denom)[0]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def masked_agg(x, mask, *, block_n: int = 4096, interpret: bool = True):
    """x: [m, n]; mask: [m]. Returns [n] fp32 (active-client mean)."""
    m, n = x.shape
    pad = (-n) % block_n
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    np_ = x.shape[1]
    mask2 = mask.astype(jnp.float32).reshape(m, 1)
    out = pl.pallas_call(
        _kernel,
        grid=(np_ // block_n,),
        in_specs=[
            pl.BlockSpec((m, 1), lambda i: (0, 0)),
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((np_,), jnp.float32),
        interpret=interpret,
    )(mask2, x)
    return out[:n]
