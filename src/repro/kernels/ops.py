"""Jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run with ``interpret=True`` (Pallas executes the
kernel body in Python); on TPU set ``interpret=False``. The model forward
paths use the pure-jnp implementations by default — the kernels are the
TPU-target hot-spot implementations, validated against ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import (
    FUSED_OPS,
    attention,
    fused_agg,
    fused_agg_pytree,
    resolve_attention_backend,
    resolve_backend,
    resolve_use_kernel,
    use_kernel_default,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.masked_agg import (
    OP_ALL,
    OP_KNOWN_P,
    OP_MEAN,
    fused_masked_agg,
    masked_agg,
)
from repro.kernels.ref import (
    flash_attention_ref,
    fused_masked_agg_ref,
    masked_agg_ref,
    rwkv6_chunk_ref,
)
from repro.kernels.rwkv6_chunk import rwkv6_chunk


def masked_agg_pytree(clients, mask, prev=None, *, interpret: bool = True):
    """FedPBC aggregation over an [m, ...] client-stacked pytree using the
    masked_agg kernel per (flattened) leaf. ``prev`` (a pytree matching the
    server params) folds the empty-active-set guard into the kernel: a
    zero-active round returns ``prev`` unchanged instead of a zeroed model."""
    def leaf(x, pv=None):
        m = x.shape[0]
        flat = x.reshape(m, -1)
        pflat = None if pv is None else pv.reshape(-1)
        out = masked_agg(flat, mask, pflat, interpret=interpret)
        return out.reshape(x.shape[1:]).astype(x.dtype)
    if prev is None:
        return jax.tree.map(leaf, clients)
    return jax.tree.map(leaf, clients, prev)


def gqa_flash_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                        interpret: bool = True):
    """q: [B, T, H, D]; k, v: [B, T, KV, D] (GQA) -> [B, T, H, D]."""
    b, t, h, d = q.shape
    kv = k.shape[2]
    rep = h // kv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    o = flash_attention(qt, kt, vt, causal=causal, window=window,
                        logit_softcap=logit_softcap, interpret=interpret)
    return o.transpose(0, 2, 1, 3)


__all__ = [
    "masked_agg",
    "masked_agg_pytree",
    "masked_agg_ref",
    "fused_masked_agg",
    "fused_masked_agg_ref",
    "fused_agg",
    "fused_agg_pytree",
    "FUSED_OPS",
    "OP_MEAN",
    "OP_ALL",
    "OP_KNOWN_P",
    "resolve_backend",
    "resolve_use_kernel",
    "use_kernel_default",
    "attention",
    "resolve_attention_backend",
    "flash_attention",
    "flash_attention_ref",
    "gqa_flash_attention",
    "rwkv6_chunk",
    "rwkv6_chunk_ref",
]
