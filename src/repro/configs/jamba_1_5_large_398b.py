"""Jamba-1.5-Large 398B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65536,
    attention=AttentionConfig(num_heads=64, num_kv_heads=8, head_dim=128, pattern="full"),
    moe=MoEConfig(num_experts=16, top_k=2),
    moe_every=2,      # MoE FFN every other layer
    attn_every=8,     # one attention layer per 8 (1:7 Mamba:attn)
    ssm=SSMConfig(state_dim=16, conv_width=4, expand=2),
    source="Jamba-1.5 [arXiv:2403.19887]",
)
