"""Llama-3.2-Vision 90B — dense GQA with cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision]. Vision encoder is a stub: input_specs
provides precomputed patch embeddings (per the assignment carve-out)."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attention=AttentionConfig(
        num_heads=64, num_kv_heads=8, head_dim=128, pattern="full", rope_theta=500000.0
    ),
    cross_attn_every=5,       # every 5th layer cross-attends to image tokens
    num_image_tokens=1024,    # stubbed ViT patch embeddings
    source="hf:meta-llama/Llama-3.2-11B-Vision (scaled to 90B layout)",
)
