"""SmolLM-135M — small dense llama-arch GQA [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    d_ff=1536,
    vocab_size=49152,
    attention=AttentionConfig(num_heads=9, num_kv_heads=3, head_dim=64, pattern="full"),
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
