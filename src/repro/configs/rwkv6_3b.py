"""RWKV6 'Finch' 3B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import AttentionConfig, ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65536,
    attention=AttentionConfig(num_heads=40, num_kv_heads=40, head_dim=64),
    rwkv=RWKVConfig(head_dim=64, decay_lora=64),
    source="Finch: RWKV-6 [arXiv:2404.05892]",
)
