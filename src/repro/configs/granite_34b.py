"""Granite-Code 34B — dense llama-arch, MQA (kv=1), non-gated MLP [arXiv:2405.04324]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    d_ff=24576,
    vocab_size=49152,
    attention=AttentionConfig(num_heads=48, num_kv_heads=1, head_dim=128, pattern="full"),
    gated_mlp=False,
    source="Granite Code Models [arXiv:2405.04324]",
)
