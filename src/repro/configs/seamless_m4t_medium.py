"""SeamlessM4T-medium — encoder-decoder, multimodal speech/text
[arXiv:2308.11596]. The mel-spectrogram + conv feature extractor frontend is
a stub: input_specs provides precomputed frame embeddings."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    d_ff=4096,
    vocab_size=256206,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=64, pattern="full"),
    num_audio_frames=1024,    # stubbed conformer frame embeddings
    gated_mlp=False,
    source="SeamlessM4T [arXiv:2308.11596]",
)
