"""Config system: model / federation / mesh / run configs.

Every assigned architecture has a module in this package exporting CONFIG.
``repro.configs.get_config(name)`` resolves an id like ``"rwkv6-3b"`` and
``reduced(cfg)`` produces the CPU-smoke variant (2 layers, d_model<=512,
<=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # 'einsum' = GShard one-hot dispatch (baseline), 'scatter' = gather/scatter
    dispatch: str = "einsum"
    router_jitter: float = 0.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM (used by jamba hybrid)."""

    state_dim: int = 16
    conv_width: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model/16)


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 'Finch' data-dependent decay linear attention."""

    head_dim: int = 64
    decay_lora: int = 64
    token_shift: bool = True


@dataclass(frozen=True)
class AttentionConfig:
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0  # 0 -> d_model // num_heads
    # pattern: 'full', 'swa' (all layers sliding window), 'local_global'
    # (alternating, gemma2), 'chunked' (block-local, llama4-style)
    pattern: str = "full"
    window: int = 4096
    logit_softcap: float = 0.0  # 0 = disabled; gemma2 uses 50.0
    rope_theta: float = 10000.0
    qk_norm: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    # family: 'dense' | 'moe' | 'ssm' (rwkv6) | 'hybrid' (jamba) |
    #         'vlm' | 'audio' (enc-dec)
    family: str = "dense"
    num_layers: int = 4
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 32000
    attention: AttentionConfig = field(default_factory=AttentionConfig)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # hybrid (jamba): one attention layer every `attn_every` layers
    attn_every: int = 0
    # MoE interleave: MoE FFN every `moe_every` layers (jamba=2, mixtral=1)
    moe_every: int = 1
    # vlm: cross-attention image layers every `cross_attn_every` layers
    cross_attn_every: int = 0
    num_image_tokens: int = 1024
    # audio enc-dec
    encoder_layers: int = 0
    num_audio_frames: int = 1024
    tie_embeddings: bool = False
    gated_mlp: bool = True  # SwiGLU (3 mats) vs classic MLP (2 mats, granite)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # final-logit softcap (gemma2)
    final_softcap: float = 0.0
    source: str = ""  # citation

    @property
    def head_dim(self) -> int:
        a = self.attention
        return a.head_dim if a.head_dim else self.d_model // a.num_heads

    def param_count(self) -> int:
        """Analytic total parameter count (embedding included)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        a = self.attention
        attn = d * hd * a.num_heads + 2 * d * hd * a.num_kv_heads + hd * a.num_heads * d
        n_mats = 3 if self.gated_mlp else 2
        dense_ffn = n_mats * d * f
        total = 0
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            p = 2 * d  # norms
            if kind in ("attn", "cross"):
                p += attn
                if kind == "cross":  # cross layer = self block + cross block
                    p += attn + 2 * d
            elif kind == "ssm":
                di = d * (self.ssm.expand if self.ssm else 2)
                n = self.ssm.state_dim if self.ssm else 16
                dtr = self._dt_rank()
                p += 2 * d * di + di * self.ssm.conv_width
                p += di * (dtr + 2 * n) + dtr * di + di * d + 2 * di
            elif kind == "rwkv":
                p += 5 * d * d  # r,k,v,g,o time-mix projections
                p += 2 * d * (self.rwkv.decay_lora if self.rwkv else 64)
                p += d * d + 2 * d * f  # channel-mix: r + k + v
            if kind != "rwkv":
                if self._is_moe_layer(i):
                    p += self.moe.num_experts * n_mats * d * f + d * self.moe.num_experts
                else:
                    p += dense_ffn
            total += p
        total += v * d + d
        if not self.tie_embeddings:
            total += v * d
        if self.family == "audio":
            total += self.encoder_layers * (attn + dense_ffn + 2 * d)
        return total

    def _dt_rank(self) -> int:
        if not self.ssm:
            return 0
        return self.ssm.dt_rank or -(-self.d_model // 16)

    def _is_moe_layer(self, i: int) -> bool:
        return bool(self.moe) and (i % max(self.moe_every, 1) == (max(self.moe_every, 1) - 1))

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' | 'rwkv' | 'cross' for layer i (FFN handled by _is_moe_layer)."""
        if self.family == "ssm":
            return "rwkv"
        if self.family == "audio":
            return "cross"  # every decoder layer cross-attends to the encoder
        if self.family == "hybrid":
            ae = max(self.attn_every, 1)
            return "attn" if (i % ae == ae - 1) else "ssm"
        if self.family == "vlm" and self.cross_attn_every:
            ce = self.cross_attn_every
            if i % ce == ce - 1:
                return "cross"
        return "attn"

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        n_moe_layers = sum(1 for i in range(self.num_layers) if self._is_moe_layer(i))
        n_mats = 3 if self.gated_mlp else 2
        inactive = (self.moe.num_experts - self.moe.top_k) * n_mats * self.d_model * self.d_ff
        return full - n_moe_layers * inactive


# ---------------------------------------------------------------------------
# Federation / mesh / run
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FederationConfig:
    algorithm: str = "fedpbc"  # fedpbc|fedavg|fedavg_all|fedau|mifa|fedavg_known_p|f3ast
    num_clients: int = 16
    local_steps: int = 5
    # placement: 'simulated' (vmap), 'stacked_data', 'pod_silo'
    placement: str = "simulated"
    scheme: str = "bernoulli"  # bernoulli|markov|cyclic
    time_varying: bool = False
    gamma: float = 0.5          # Eq. (9) fluctuation
    period: int = 40            # Eq. (9) sine period
    delta: float = 0.02         # p_i clip lower bound
    sigma0: float = 10.0        # lognormal class-weight spread
    alpha: float = 0.1          # Dirichlet non-IID
    cyclic_length: int = 100
    cyclic_reset: bool = False
    fedau_K: int = 50
    f3ast_beta: float = 0.01
    f3ast_cap: int = 10
    known_p: bool = False


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


ARCH_IDS = (
    "rwkv6-3b",
    "deepseek-coder-33b",
    "granite-34b",
    "smollm-135m",
    "jamba-1.5-large-398b",
    "llama-3.2-vision-90b",
    "gemma2-9b",
    "seamless-m4t-medium",
    "mixtral-8x22b",
    "llama4-maverick-400b-a17b",
)

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULE_FOR:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(_MODULE_FOR)}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[name]}")
    return mod.CONFIG


def reduced(cfg: ModelConfig, d_model: int = 256, layers: int = 2) -> ModelConfig:
    """Reduced smoke-test variant of the same family (<=512 d_model, <=4 experts)."""
    a = cfg.attention
    heads = max(2, min(4, a.num_heads))
    kv = max(1, min(heads, a.num_kv_heads if a.num_kv_heads < a.num_heads else heads))
    while heads % kv:
        kv -= 1
    att = replace(
        a,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=d_model // heads,
        window=min(a.window, 64),
    )
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        d_ff=2 * d_model,
        vocab_size=512,
        attention=att,
        num_image_tokens=min(cfg.num_image_tokens, 16),
        num_audio_frames=min(cfg.num_audio_frames, 16),
    )
    if cfg.moe:
        kw["moe"] = replace(cfg.moe, num_experts=min(4, cfg.moe.num_experts))
    if cfg.ssm:
        kw["ssm"] = replace(cfg.ssm, state_dim=8)
    if cfg.rwkv:
        kw["rwkv"] = replace(cfg.rwkv, head_dim=d_model // heads, decay_lora=16)
    if cfg.family == "hybrid":
        kw["num_layers"] = max(layers, cfg.attn_every)  # keep one full period? no: cap
        kw["num_layers"] = layers
        kw["attn_every"] = 2
    if cfg.family == "vlm":
        kw["cross_attn_every"] = 2
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
    return replace(cfg, **kw)


def long_context_capable(cfg: ModelConfig) -> bool:
    """True if the arch may run long_500k (sub-quadratic / bounded-cache attn)."""
    if cfg.family in ("ssm", "hybrid"):
        return True
    if cfg.family == "audio":
        return False
    return cfg.attention.pattern in ("swa", "local_global", "chunked")


def applicable_shapes(cfg: ModelConfig):
    out = []
    for s in INPUT_SHAPES.values():
        if s.name == "long_500k" and not long_context_capable(cfg):
            continue
        out.append(s)
    return out
