"""Gemma-2 9B — local/global alternating attention, logit softcaps
[arXiv:2408.00118]."""
from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attention=AttentionConfig(
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        pattern="local_global",
        window=4096,
        logit_softcap=50.0,
    ),
    tie_embeddings=True,
    final_softcap=30.0,
    source="Gemma 2 [arXiv:2408.00118]",
)
