"""Mixtral 8x22B — MoE 8 experts top-2, sliding-window attention
[arXiv:2401.04088]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    d_ff=16384,
    vocab_size=32768,
    attention=AttentionConfig(
        num_heads=48, num_kv_heads=8, head_dim=128, pattern="swa", window=4096
    ),
    moe=MoEConfig(num_experts=8, top_k=2),
    moe_every=1,
    source="Mixtral of Experts [arXiv:2401.04088]",
)
