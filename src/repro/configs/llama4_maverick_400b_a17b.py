"""Llama-4 Maverick 400B (17B active) — MoE 128 experts top-1, interleaved
dense/MoE, chunked (block-local) attention, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202048,
    attention=AttentionConfig(
        num_heads=40, num_kv_heads=8, head_dim=128, pattern="chunked", window=8192
    ),
    moe=MoEConfig(num_experts=128, top_k=1),
    moe_every=2,  # MoE every other layer (dense/MoE interleave)
    source="hf:meta-llama/Llama-4-Scout-17B-16E (Maverick layout)",
)
