"""Vmapped multi-seed experiment runner + the sweep CLI.

One `(algorithm, link-scheme)` grid cell of a paper table is S seeded
repetitions of the same program. ``make_vmap_run_rounds`` vmaps the ENTIRE
per-seed pipeline —

    init params -> init_fed_state -> K rounds (lax.scan) -> periodic eval

— over a leading seed axis, so all S repetitions execute as ONE compiled
device program: per-seed PRNG keys and per-seed Eq.-9 ``p_base`` vectors are
batched inputs, the dataset is a shared jit constant, and metrics come back
stacked ``[S, K, ...]`` (evals ``[S, E]``). Compared with the sequential
per-seed loop (``benchmarks/common.run_training`` called S times) this
removes S-1 compilations and all per-seed dispatch — the ``lax.scan`` engine
of PR 1 collapsed the round axis; this collapses the seed axis on top of it.

The link process is built INSIDE the vmapped function from the traced
``p_base`` argument (``link_factory``), which is what lets seeds differ in
their connection-probability draw without recompiling.

CLI::

    PYTHONPATH=src python -m repro.experiments.sweep \
        --algos fedpbc,fedavg --schemes bernoulli_ti,markov_hom \
        --seeds 0,1,2 --rounds 100 --clients 32 --out benchmarks/out/sweeps
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig
from repro.core.algorithms import Algorithm
from repro.core.federated import (
    DEFAULT_METRIC_KEYS,
    init_fed_state,
    make_round_fn,
    make_round_step,
)


def seed_keys(seed: int):
    """The per-seed key bundle. Matches the historical layout of
    ``benchmarks/common.run_training`` (params=seed+1, state=seed+2,
    ds=seed+3, data=seed+4) so migrated suites keep their key protocol."""
    return {
        "params": jax.random.PRNGKey(seed + 1),
        "state": jax.random.PRNGKey(seed + 2),
        "ds": jax.random.PRNGKey(seed + 3),
        "data": jax.random.PRNGKey(seed + 4),
    }


def stack_seed_keys(seeds):
    """Stack per-seed key bundles into one [S]-batched pytree."""
    bundles = [seed_keys(s) for s in seeds]
    return jax.tree.map(lambda *ks: jnp.stack(ks), *bundles)


def make_vmap_run_rounds(loss_fn: Callable, optimizer, algorithm: Algorithm,
                         fed_cfg: FederationConfig, source, *,
                         link_factory: Callable,
                         init_params: Callable,
                         num_rounds: int,
                         eval_every: int = 0,
                         eval_fn: Optional[Callable] = None,
                         metric_keys=DEFAULT_METRIC_KEYS):
    """Build the jitted S-seed runner for one grid cell.

    Args:
      link_factory: ``p_base [m] -> LinkProcess`` (e.g.
        ``lambda p: make_link_process(p, fed_cfg)``); called on the traced
        per-seed probability vector inside the vmapped trace.
      init_params: ``key -> model params`` (per-seed model init).
      num_rounds: static total round count K.
      eval_every / eval_fn: when both set, ``eval_fn(server_params)`` runs
        every ``eval_every`` rounds *inside* the compiled program (plus once
        at round K when K is not a multiple), and the result comes back as
        ``out["evals"] [S, E]`` with boundaries ``eval_rounds(...)``.

    Returns ``run(keys, p_base) -> (states, out)`` where ``keys`` is a
    ``stack_seed_keys`` bundle, ``p_base`` is ``[S, m]``, ``states`` is an
    [S]-batched ``FedState`` and ``out["metrics"]`` maps each metric key to a
    ``[S, K, ...]`` array. Bit-for-bit equal (per seed) to S independent
    ``make_run_rounds`` trajectories with the same keys —
    ``tests/test_sweep.py`` enforces this.

    The runner is two compiled programs, not one: a (cheap) batched init and
    the batched round scan, with the [S]-batched state passed BETWEEN them as
    a device array. Fusing init into the same program as the scan lets XLA
    compile the scan body in a different fusion context, which on CPU can
    perturb float reductions by 1 ulp — the split keeps the scan stage's
    abstract signature identical in structure to ``make_run_rounds`` and is
    what makes per-seed bitwise equality hold.
    """
    do_eval = eval_fn is not None and eval_every > 0
    n_chunks, rem = divmod(num_rounds, eval_every) if do_eval else (0, num_rounds)

    def init_seed(keys, p_base):
        link = link_factory(p_base)
        params = init_params(keys["params"])
        st = init_fed_state(keys["state"], params, fed_cfg, algorithm, link,
                            optimizer)
        return st, source.init(keys["ds"])

    def scan_seed(st, ds, data_key, p_base):
        link = link_factory(p_base)
        round_fn = make_round_fn(loss_fn, optimizer, algorithm, link, fed_cfg)
        step = make_round_step(round_fn, source)

        def body(carry, _):
            st, ds = carry
            st, ds, mets = step(st, ds, data_key)
            return (st, ds), {k: mets[k] for k in metric_keys}

        def run_span(carry, length):
            return jax.lax.scan(body, carry, None, length=length)

        if not do_eval:
            (st, ds), mets = run_span((st, ds), num_rounds)
            return st, {"metrics": mets}

        def chunk(carry, _):
            carry, mets = run_span(carry, eval_every)
            return carry, (mets, eval_fn(carry[0].server))

        carry, (mets, evals) = jax.lax.scan(chunk, (st, ds), None,
                                            length=n_chunks)
        # [E, eval_every, ...] -> [E * eval_every, ...]
        mets = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), mets)
        if rem:
            carry, tail = run_span(carry, rem)
            mets = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), mets, tail)
            evals = jnp.concatenate([evals, eval_fn(carry[0].server)[None]])
        st, ds = carry
        return st, {"metrics": mets, "evals": evals}

    init_batch = jax.jit(jax.vmap(init_seed))
    scan_batch = jax.jit(jax.vmap(scan_seed))

    def run(keys, p_base):
        st, ds = init_batch(keys, p_base)
        return scan_batch(st, ds, keys["data"], p_base)

    return run


def eval_rounds(num_rounds: int, eval_every: int):
    """Round indices (1-based) at which the runner's evals fire.
    ``eval_every <= 0`` means a single eval at the final round."""
    if eval_every <= 0:
        return [num_rounds]
    n_chunks, rem = divmod(num_rounds, eval_every)
    out = [eval_every * (i + 1) for i in range(n_chunks)]
    if rem:
        out.append(num_rounds)
    return out


def main(argv=None) -> None:
    import argparse

    # lazy: grid imports this module
    from repro.experiments.grid import ALGOS, SCHEMES, SweepSpec, run_sweep
    from repro.experiments.results import ResultsStore

    ap = argparse.ArgumentParser(
        description="Run a (algorithm x scheme x seed) sweep on the vmapped "
                    "engine and append results to a JSONL/npz store.")
    ap.add_argument("--algos", default="fedpbc,fedavg",
                    help=f"comma list from {','.join(ALGOS)}")
    ap.add_argument("--schemes", default="bernoulli_ti",
                    help=f"comma list from {','.join(SCHEMES)}")
    ap.add_argument("--seeds", default="0,1,2", help="comma list of ints")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--delta", type=float, default=0.02)
    ap.add_argument("--sigma0", type=float, default=10.0)
    ap.add_argument("--out", default="benchmarks/out/sweeps",
                    help="results-store directory (JSONL + npz)")
    ap.add_argument("--suite", default="cli", help="suite tag on the records")
    args = ap.parse_args(argv)

    spec = SweepSpec(
        algorithms=tuple(args.algos.split(",")),
        schemes=tuple(args.schemes.split(",")),
        seeds=tuple(int(s) for s in args.seeds.split(",")),
        rounds=args.rounds, eval_every=args.eval_every,
        num_clients=args.clients, local_steps=args.local_steps,
        alpha=args.alpha, gamma=args.gamma, delta=args.delta,
        sigma0=args.sigma0)
    store = ResultsStore(args.out)
    print("sweep,scheme,algo,seeds,test_acc_mean,test_acc_ci95,train_acc_mean",
          flush=True)
    for cell in run_sweep(spec, store=store, suite=args.suite):
        s = cell.summary()
        print(f"sweep,{cell.scheme},{cell.algo},{len(cell.seeds)},"
              f"{s['test_acc']['mean']:.4f},{s['test_acc']['ci95']:.4f},"
              f"{s['train_acc']['mean']:.4f}", flush=True)
    print(f"# results appended to {store.path}", flush=True)


if __name__ == "__main__":
    main()
