"""Batched experiment runner (seed x hyperparameter axis) + the sweep CLI.

One `(algorithm, link-scheme)` grid cell of a paper table used to be S seeded
repetitions of one program; with the hyperparameter axis it is B = P x S
trajectories — P hyperparameter points (a flattened lr x gamma x alpha x
sigma0 x delta product) times S seeds. ``make_batched_run_rounds`` vmaps the
ENTIRE per-trajectory pipeline —

    init params -> init_fed_state -> K rounds (lax.scan) -> periodic eval

— over that one leading batch axis, so all B trajectories execute as ONE
compiled device program. *Everything that varies within a sweep enters as a
traced input*, carried by a ``CellBatch``:

- ``keys``     per-trajectory PRNG key bundles (leaves ``[B, 2]``);
- ``p_base``   per-trajectory Eq.-9 connection probabilities ``[B, m]``
  (alpha/sigma0/delta reach the program only through this input);
- ``hparams``  per-trajectory traced scalars (``lr``, ``gamma``, ``period``)
  the factories consume *inside* the trace — the optimizer's schedule and the
  link process are built from traced values, not baked closures;
- ``data``     per-trajectory ``ds_state`` (e.g. the Dirichlet(alpha)
  partition ``idx [B, m, per_client]``);
- ``shared``   the unbatched dataset arrays, traced but vmapped with
  ``in_axes=None`` so B trajectories share one device copy;
- ``algo_id``  per-trajectory algorithm index ``[B]`` into an
  ``AlgorithmSpec`` family table — the *algorithm axis*. When the runner is
  built from a spec (``repro.core.AlgorithmSpec``), client-start/aggregate
  lower to a branchless ``lax.switch``/select over the family's branch table,
  so every state-compatible algorithm (e.g. the whole
  fedavg/fedavg_all/fedavg_known_p/fedpbc family) shares ONE compiled program
  and the algorithm axis flattens into the batch dimension alongside points
  and seeds.

Only *structural* knobs still recompile: the (algorithm family, scheme) pair
(distinct ``algo_state``/``link_state`` pytree shapes and branch tables),
round counts, and array shapes (num_clients, per_client, model dims, batch
size).

``make_vmap_run_rounds`` — the PR-2 seed-axis API — is a thin wrapper that
runs a single-point batch with constant data/optimizer; migrated suites and
its bit-for-bit guarantees are unchanged.

CLI::

    PYTHONPATH=src python -m repro.experiments.sweep \
        --algos fedpbc,fedavg --schemes bernoulli_ti,markov_hom \
        --seeds 0,1,2 --lrs 0.05,0.1 --alphas 0.1,1.0 \
        --rounds 100 --clients 32 --out benchmarks/out/sweeps
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import FederationConfig
from repro.core.algorithms import Algorithm, AlgorithmSpec, as_algorithm
from repro.core.federated import (
    DEFAULT_METRIC_KEYS,
    init_fed_state,
    make_round_fn,
    make_round_step,
)
from repro.data.sources import DataSource
from repro.scale.buffer import STRATEGY_KNOB_FIELDS
from repro.sharding.specs import spec_for_shape

Pytree = Any


def seed_keys(seed: int):
    """The per-seed key bundle. Matches the historical layout of
    ``benchmarks/common.run_training`` (params=seed+1, state=seed+2,
    ds=seed+3, data=seed+4) so migrated suites keep their key protocol."""
    return {
        "params": jax.random.PRNGKey(seed + 1),
        "state": jax.random.PRNGKey(seed + 2),
        "ds": jax.random.PRNGKey(seed + 3),
        "data": jax.random.PRNGKey(seed + 4),
    }


def stack_seed_keys(seeds):
    """Stack per-seed key bundles into one [S]-batched pytree."""
    bundles = [seed_keys(s) for s in seeds]
    return jax.tree.map(lambda *ks: jnp.stack(ks), *bundles)


@dataclass
class CellBatch:
    """Everything one (algorithm-family, scheme) cell's compiled program
    consumes.

    All fields are pytrees; ``keys``/``p_base``/``hparams``/``data``/
    ``algo_id`` carry a leading ``[B]`` batch axis (B = algos x points x
    seeds), ``shared`` is unbatched (one device copy serves every
    trajectory). ``algo_id`` is the traced per-trajectory index into the
    runner's ``AlgorithmSpec`` table; the default ``()`` (no algorithm axis)
    keeps the historical single-algorithm program. Registered as a pytree so
    a batch can be sliced/saved/donated like any other JAX value.
    """

    keys: Pytree        # seed-key bundles, leaves [B, 2]
    p_base: Pytree      # [B, m] Eq.-9 connection probabilities
    hparams: Pytree     # dict of [B] traced scalars (lr, gamma, period, ...)
    data: Pytree        # per-trajectory ds_state (leaves [B, ...])
    shared: Pytree      # unbatched dataset arrays
    algo_id: Pytree = ()  # [B] int32 AlgorithmSpec indices, or () (no axis)

    @property
    def batch_size(self) -> int:
        return jax.tree.leaves(self.p_base)[0].shape[0]


jax.tree_util.register_dataclass(
    CellBatch,
    data_fields=["keys", "p_base", "hparams", "data", "shared", "algo_id"],
    meta_fields=[],
)


def make_batched_run_rounds(loss_fn: Callable, algorithm,
                            fed_cfg: FederationConfig, *,
                            optimizer_factory: Callable,
                            link_factory: Callable,
                            source_factory: Callable,
                            init_params: Callable,
                            num_rounds: int,
                            eval_every: int = 0,
                            eval_fn: Optional[Callable] = None,
                            metric_keys=DEFAULT_METRIC_KEYS,
                            use_kernel: bool = False,
                            cohort_size: Optional[int] = None,
                            buffered: bool = False,
                            shard_mesh=None,
                            carry_out: bool = False,
                            donate_carry: Optional[bool] = None):
    """Build the jitted B-trajectory runner for one grid cell.

    Args:
      algorithm: an ``Algorithm`` (single rule, static dispatch — the
        historical program), or an ``AlgorithmSpec`` family table. With a
        spec, the batch's traced per-trajectory ``algo_id`` selects each
        trajectory's rule through the family's branchless switch, so one
        compiled program serves every member; a batch without an algorithm
        axis (``algo_id=()``) binds the spec's first entry statically.
      optimizer_factory: ``hparams -> Optimizer`` (e.g.
        ``lambda hp: sgd(paper_decay(hp["lr"]))``); called on the traced
        per-trajectory hparam scalars inside the trace, so swept LRs share one
        compile.
      link_factory: ``(p_base [m], hparams) -> LinkProcess`` (e.g.
        ``lambda p, hp: make_link_process(p, fed_cfg, gamma=hp["gamma"])``).
      source_factory: ``shared -> DataSource`` whose ``init(key, data)``
        consumes the per-trajectory ``data`` pytree (see
        ``repro.data.sources.traced_classification_source``).
      init_params: ``key -> model params`` (per-trajectory model init).
      num_rounds: static total round count K.
      eval_every / eval_fn: when both set, ``eval_fn(server_params, shared)``
        runs every ``eval_every`` rounds *inside* the compiled program, under
        the contract "always at least one eval, the last at round K": a final
        eval fires at round K when K is not a multiple of ``eval_every`` —
        including K == 0, where the single eval measures the freshly
        initialized model (E is never 0). ``eval_every == K`` fires exactly
        one eval, at K. The result comes back as ``out["evals"] [B, E]`` with
        boundaries ``eval_rounds(...)``.
      use_kernel: route a fusable family's server aggregation through the
        backend-dispatched fused Pallas kernel (one pass per leaf, branch
        select inside the kernel body) instead of the XLA masked-mean
        switch; see ``repro.kernels.dispatch`` for backend resolution and
        the per-backend tolerance contract. The traced program shape is
        unchanged — one compiled (init, scan) pair still serves the whole
        family.
      cohort_size / buffered: the cross-device scale modes (``repro.scale``),
        requiring an ``AlgorithmSpec``. ``cohort_size=C`` subsamples C
        clients per round on device (stateless clients, O(C) round memory).
        ``buffered=True`` routes a fusable family's aggregation through the
        buffered semi-async engine, reading the per-trajectory strategy
        knobs (``repro.scale.STRATEGY_KNOB_FIELDS``) from ``hparams`` — the
        strategy axis is one more traced batched dimension, zero extra
        compiles.
      shard_mesh: a 2-D ``("batch", "model")`` mesh
        (``repro.launch.mesh.make_2d_mesh``) turning the runner into the
        sharded-LM execution path: the trajectory vmaps carry
        ``spmd_axis_name="batch"``, the round's client vmap carries
        ``spmd_axis_name="model"`` (local training parallel over clients,
        each client's model whole on its device), and the ``FedState`` is
        constrained so server parameters shard per-leaf over ``"model"``
        (``repro.sharding.spec_for_shape``) and client/optimizer stacks
        shard their leading client axis over ``"model"``. Before any
        cross-client reduction the local updates are gathered back to
        model-replicated (``gather_updates``), so the aggregation step is
        computed redundantly-but-identically on every device and
        introduces no divergence by construction. The remaining divergence
        source is XLA itself: per-client forward/backward compiles at
        per-device client shapes (m/model_axis rows instead of m), and on
        CPU the fusion chosen at a different shape can reassociate a
        reduction by ~1 ulp. Observed reach: the forward-only scalar loss
        telemetry in ``out["metrics"]`` (feeds neither gradients nor
        state), and in cohort mode occasionally the gradients themselves
        (~1e-8 in server params). The pinned shapes in
        ``tests/test_lm_sweep.py`` are bitwise across the board —
        state, evals and metrics — and deterministically so; at other
        shapes treat state/evals as allclose(1e-6) and metrics as
        allclose(1e-5). The final state is
        gathered to model-replicated so downstream host-side evals see
        plain batch-sharded arrays. Feed the result through
        ``repro.experiments.shard.run_sharded_2d``.
      carry_out: the resumable *scan-segment* mode (the adaptive-search
        driver's building block). The scan stage returns
        ``((states, ds_states), out)`` instead of ``(states, out)`` — the
        full [B]-batched ``(FedState, ds_state)`` carry comes back as device
        arrays, so a caller can run ``num_rounds``-sized segments back to
        back: ``carry = run.init(batch)``, then repeatedly
        ``carry, out = run.step(carry, batch)``. Because the round step's
        data key is a pure function of the carried round counter
        (``make_round_step`` folds ``state.round`` into ``data_key``) and
        the link/optimizer state ride the carry, k chained segments are
        bit-for-bit equal to one uninterrupted ``k * num_rounds`` program
        with the same eval cadence (``tests/test_search.py``).
      donate_carry: in ``carry_out`` mode, donate the incoming ``(st, ds)``
        carry buffers to the scan stage so each segment updates in place
        instead of doubling the [B]-state footprint. Defaults to backend !=
        "cpu" — the same gate as ``make_run_rounds`` (CPU ignores donation
        noisily). After ``run.step(carry, ...)`` the passed carry is dead on
        donating backends; rebind, never reuse.

    Returns ``run(batch: CellBatch) -> (states, out)`` where ``states`` is a
    [B]-batched ``FedState`` and ``out["metrics"]`` maps each metric key to a
    ``[B, K, ...]`` array. Each trajectory is bit-for-bit equal to an
    independent sequential ``make_run_rounds`` run with the same key bundle
    and that point's knobs baked as constants — ``tests/test_sweep.py`` and
    ``tests/test_traced_axes.py`` enforce this.

    The runner is two compiled programs, not one: a (cheap) batched init and
    the batched round scan, with the [B]-batched state passed BETWEEN them as
    a device array. Fusing init into the same program as the scan lets XLA
    compile the scan body in a different fusion context, which on CPU can
    perturb float reductions by 1 ulp — the split keeps the scan stage's
    abstract signature identical in structure to ``make_run_rounds`` and is
    what makes per-trajectory bitwise equality hold. The two jitted stages
    are exposed as ``run.init_batch`` / ``run.scan_batch`` so callers (the
    compile-counter test, benchmarks) can read their compile-cache sizes.
    """
    do_eval = eval_fn is not None and eval_every > 0
    n_chunks, rem = divmod(num_rounds, eval_every) if do_eval else (0, num_rounds)
    scale_mode = buffered or cohort_size is not None
    if scale_mode and not isinstance(algorithm, AlgorithmSpec):
        raise ValueError(
            "cohort_size/buffered need an AlgorithmSpec runner (got "
            f"{type(algorithm).__name__})")
    # stateful rules take the sparse cohort path; only fusable families
    # thread a BufferState
    has_buffer = scale_mode and isinstance(algorithm, AlgorithmSpec) \
        and algorithm.fusable
    if shard_mesh is not None and not (
            {"batch", "model"} <= set(shard_mesh.axis_names)):
        raise ValueError(
            f'shard_mesh needs ("batch", "model") axes, got '
            f"{shard_mesh.axis_names}")
    spmd_model = "model" if shard_mesh is not None else None

    def _wsc(x, spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(shard_mesh, spec))

    def _replicate(tree):
        """Gather every leaf to model-replicated (specs written here are the
        per-trajectory view — the trajectory vmap's spmd_axis_name prepends
        "batch" on the mapped dim)."""
        if shard_mesh is None:
            return tree
        return jax.tree.map(lambda x: _wsc(x, P()), tree)

    gather = _replicate if shard_mesh is not None else None
    if eval_fn is not None and shard_mesh is not None:
        _base_eval = eval_fn
        # in-program evals reduce over the dataset: gather the (possibly
        # model-sharded) server params first so the reduction is computed
        # identically on every device
        eval_fn = lambda params, shared: _base_eval(_replicate(params), shared)  # noqa: E731

    def _constrain_state(st):
        """Pin the carried FedState's placement: server per-leaf over
        "model" (tensor sharding), client/optimizer stacks over their
        leading client axis. Constraining the scan carry keeps the layout
        stable across rounds instead of letting GSPMD re-derive it."""
        if shard_mesh is None:
            return st

        def client_leaf(x):
            return _wsc(x, P("model")) if x.ndim >= 1 else x

        return dataclasses.replace(
            st,
            server=jax.tree.map(
                lambda x: _wsc(x, spec_for_shape(x.shape, shard_mesh)),
                st.server),
            clients=jax.tree.map(client_leaf, st.clients),
            opt_state=jax.tree.map(client_leaf, st.opt_state))

    def _bound(algo_id):
        """Resolve the per-trajectory dispatch: a traced ``algo_id`` scalar
        selects through the spec's switch; an absent axis (the empty-pytree
        default) is the historical static program."""
        if isinstance(algo_id, tuple) and algo_id == ():
            algo_id = 0
        return as_algorithm(algorithm, algo_id, use_kernel=use_kernel)

    def init_point(keys, p_base, hparams, data, shared, algo_id):
        algo = _bound(algo_id)
        optimizer = optimizer_factory(hparams)
        link = link_factory(p_base, hparams)
        source = source_factory(shared)
        params = init_params(keys["params"])
        st = init_fed_state(keys["state"], params, fed_cfg, algo, link,
                            optimizer,
                            stateless_clients=cohort_size is not None,
                            buffered=has_buffer)
        return _constrain_state(st), source.init(keys["ds"], data)

    def scan_point(st, ds, data_key, p_base, hparams, shared, algo_id):
        optimizer = optimizer_factory(hparams)
        link = link_factory(p_base, hparams)
        source = source_factory(shared)
        if scale_mode:
            # the scale engines dispatch the spec themselves (they need the
            # family table, not a bound Algorithm)
            aid = 0 if (isinstance(algo_id, tuple) and algo_id == ()) \
                else algo_id
            strat = ({k: hparams[k] for k in STRATEGY_KNOB_FIELDS}
                     if buffered else None)
            round_fn = make_round_fn(loss_fn, optimizer, algorithm, link,
                                     fed_cfg, spmd_axis_name=spmd_model,
                                     algo_id=aid, strategy=strat,
                                     cohort_size=cohort_size,
                                     gather_updates=gather)
        else:
            round_fn = make_round_fn(loss_fn, optimizer, _bound(algo_id),
                                     link, fed_cfg,
                                     spmd_axis_name=spmd_model,
                                     gather_updates=gather)
        step = make_round_step(round_fn, source)

        def body(carry, _):
            st, ds = carry
            st, ds, mets = step(st, ds, data_key)
            return (_constrain_state(st), ds), {k: mets[k] for k in metric_keys}

        def run_span(carry, length):
            return jax.lax.scan(body, carry, None, length=length)

        if not do_eval:
            (st, ds), mets = run_span((st, ds), num_rounds)
            if carry_out:
                return (st, ds), {"metrics": mets}
            # final all-gather: downstream consumers (host-side evals,
            # rows()) see model-replicated, batch-sharded state
            return _replicate(st), {"metrics": mets}

        def chunk(carry, _):
            carry, mets = run_span(carry, eval_every)
            return carry, (mets, eval_fn(carry[0].server, shared))

        carry, (mets, evals) = jax.lax.scan(chunk, (st, ds), None,
                                            length=n_chunks)
        # [E, eval_every, ...] -> [E * eval_every, ...]
        mets = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), mets)
        if rem or n_chunks == 0:
            # the remainder tail, plus the >= 1 eval guarantee: at K == 0
            # (rem == n_chunks == 0) this runs a zero-length span and evals
            # the freshly initialized model once
            carry, tail = run_span(carry, rem)
            mets = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], 0), mets, tail)
            evals = jnp.concatenate(
                [evals, eval_fn(carry[0].server, shared)[None]])
        st, ds = carry
        if carry_out:
            return (st, ds), {"metrics": mets, "evals": evals}
        return _replicate(st), {"metrics": mets, "evals": evals}

    spmd_batch = "batch" if shard_mesh is not None else None
    init_batch = jax.jit(jax.vmap(init_point, in_axes=(0, 0, 0, 0, None, 0),
                                  spmd_axis_name=spmd_batch))
    # carry_out segments update the [B]-state in place (donated (st, ds))
    # so chaining rungs never doubles the state footprint; the historical
    # one-shot mode keeps its undonated signature untouched
    if donate_carry is None:
        donate_carry = jax.default_backend() != "cpu"  # CPU ignores donation
    donate = (0, 1) if (carry_out and donate_carry) else ()
    scan_batch = jax.jit(jax.vmap(scan_point,
                                  in_axes=(0, 0, 0, 0, 0, None, 0),
                                  spmd_axis_name=spmd_batch),
                         donate_argnums=donate)

    def init(batch: CellBatch):
        """The batched init stage alone: the [B] (FedState, ds_state) carry."""
        return init_batch(batch.keys, batch.p_base, batch.hparams,
                          batch.data, batch.shared, batch.algo_id)

    def step(carry, batch: CellBatch):
        """One scan dispatch from an existing carry. In ``carry_out`` mode
        this is the resumable segment: returns ``(next_carry, out)`` and (on
        donating backends) consumes the passed carry's buffers."""
        st, ds = carry
        return scan_batch(st, ds, batch.keys["data"], batch.p_base,
                          batch.hparams, batch.shared, batch.algo_id)

    def run(batch: CellBatch):
        return step(init(batch), batch)

    run.init = init
    run.step = step
    run.init_batch = init_batch
    run.scan_batch = scan_batch
    run.shard_mesh = shard_mesh
    run.carry_out = carry_out
    return run


def make_vmap_run_rounds(loss_fn: Callable, optimizer, algorithm: Algorithm,
                         fed_cfg: FederationConfig, source, *,
                         link_factory: Callable,
                         init_params: Callable,
                         num_rounds: int,
                         eval_every: int = 0,
                         eval_fn: Optional[Callable] = None,
                         metric_keys=DEFAULT_METRIC_KEYS):
    """The PR-2 seed-axis runner: S seeds of one cell as one program, with the
    optimizer and the dataset (a regular constant-capturing ``DataSource``)
    baked at build time.

    Now a thin wrapper over ``make_batched_run_rounds`` running a single
    hyperparameter point: hparams/data/shared are empty pytrees, so the traced
    program is the historical one and per-seed trajectories remain bit-for-bit
    equal to the sequential path (``tests/test_sweep.py``).

    Returns ``run(keys, p_base) -> (states, out)`` where ``keys`` is a
    ``stack_seed_keys`` bundle and ``p_base`` is ``[S, m]``.
    """
    core = make_batched_run_rounds(
        loss_fn, algorithm, fed_cfg,
        optimizer_factory=lambda hp: optimizer,
        link_factory=lambda p, hp: link_factory(p),
        source_factory=lambda shared: DataSource(
            lambda key, data: source.init(key), source.sample, source.name),
        init_params=init_params,
        num_rounds=num_rounds,
        eval_every=eval_every,
        eval_fn=(lambda params, shared: eval_fn(params))
                if eval_fn is not None else None,
        metric_keys=metric_keys)

    def run(keys, p_base):
        return core(CellBatch(keys=keys, p_base=p_base, hparams={}, data=(),
                              shared=()))

    run.init_batch = core.init_batch
    run.scan_batch = core.scan_batch
    return run


def eval_rounds(num_rounds: int, eval_every: int):
    """Round indices (1-based) at which the runner's evals fire.

    Contract (mirrored by ``make_batched_run_rounds``): at least one eval,
    the last at ``num_rounds`` — so ``eval_every == num_rounds`` fires exactly
    one final eval, and ``num_rounds == 0`` evals the initial model once (at
    "round 0"). ``eval_every <= 0`` means a single eval at the final round.
    """
    if eval_every <= 0:
        return [num_rounds]
    n_chunks, rem = divmod(num_rounds, eval_every)
    out = [eval_every * (i + 1) for i in range(n_chunks)]
    if rem or not out:
        out.append(num_rounds)
    return out


def _float_list(text: str):
    return tuple(float(v) for v in text.split(",")) if text else ()


def main(argv=None) -> None:
    import argparse

    # lazy: grid imports this module
    from repro.experiments.grid import ALGOS, SCHEMES, SweepSpec, run_sweep
    from repro.experiments.results import ResultsStore

    ap = argparse.ArgumentParser(
        description="Run a (algorithm x scheme x hyperparameter x seed) sweep "
                    "on the batched engine and append results to a JSONL/npz "
                    "store. Each --lrs/--gammas/--alphas/--sigma0s/--deltas "
                    "axis — and every state-compatible group of --algos "
                    "(e.g. fedpbc,fedavg,fedavg_all,fedavg_known_p) — is "
                    "swept inside ONE compiled program per "
                    "(algorithm family, scheme).")
    ap.add_argument("--algos", default="fedpbc,fedavg",
                    help=f"comma list from {','.join(ALGOS)}")
    ap.add_argument("--schemes", default="bernoulli_ti",
                    help=f"comma list from {','.join(SCHEMES)}")
    ap.add_argument("--seeds", default="0,1,2", help="comma list of ints")
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--eval-every", type=int, default=25)
    ap.add_argument("--clients", type=int, default=100)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--gamma", type=float, default=0.5)
    ap.add_argument("--delta", type=float, default=0.02)
    ap.add_argument("--sigma0", type=float, default=10.0)
    ap.add_argument("--lrs", default="", help="comma list; hyperparameter "
                    "axis overriding --lr (traced, no recompile)")
    ap.add_argument("--gammas", default="", help="axis overriding --gamma")
    ap.add_argument("--alphas", default="", help="axis overriding --alpha")
    ap.add_argument("--sigma0s", default="", help="axis overriding --sigma0")
    ap.add_argument("--deltas", default="", help="axis overriding --delta")
    ap.add_argument("--task", default="classification",
                    choices=("classification", "lm"),
                    help="client workload: the paper's classification task "
                    "or the smollm-class reduced LM (next-token loss over "
                    "the styled byte-level corpus)")
    ap.add_argument("--lm-d-model", type=int, default=64,
                    help="LM task: reduced model width")
    ap.add_argument("--lm-layers", type=int, default=2,
                    help="LM task: reduced layer count")
    ap.add_argument("--lm-seq", type=int, default=32,
                    help="LM task: training sequence length")
    ap.add_argument("--cohort", type=int, default=None,
                    help="per-round cohort size C (cross-device scale mode: "
                    "stateless clients, O(C) round memory)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="add a buffered semi-async strategy arm committing "
                    "when this many updates have arrived (0: sync only)")
    ap.add_argument("--deadline-rounds", type=int, default=4,
                    help="buffered arm: commit after this many rounds even "
                    "if the buffer has not filled")
    ap.add_argument("--staleness-discount", type=float, default=0.0,
                    help="buffered arm: per-round decay of the standing "
                    "buffer, in [0, 1)")
    ap.add_argument("--wait-for-full", action="store_true",
                    help="buffered arm: commit ONLY when the buffer fills "
                    "(ignore the deadline)")
    ap.add_argument("--buffered-only", action="store_true",
                    help="drop the sync arm when --buffer-size is set")
    ap.add_argument("--out", default="benchmarks/out/sweeps",
                    help="results-store directory (JSONL + npz)")
    ap.add_argument("--suite", default="cli", help="suite tag on the records")
    args = ap.parse_args(argv)

    from repro.scale import SYNC, Strategy

    strategies = (SYNC,)
    if args.buffer_size:
        arm = Strategy("buffered", wait_for_full=args.wait_for_full,
                       buffer_size=args.buffer_size,
                       deadline_rounds=args.deadline_rounds,
                       staleness_discount=args.staleness_discount)
        strategies = (arm,) if args.buffered_only else (SYNC, arm)
    spec = SweepSpec(
        algorithms=tuple(args.algos.split(",")),
        schemes=tuple(args.schemes.split(",")),
        seeds=tuple(int(s) for s in args.seeds.split(",")),
        rounds=args.rounds, eval_every=args.eval_every,
        num_clients=args.clients, local_steps=args.local_steps,
        lr=args.lr, alpha=args.alpha, gamma=args.gamma, delta=args.delta,
        sigma0=args.sigma0,
        lrs=_float_list(args.lrs), gammas=_float_list(args.gammas),
        alphas=_float_list(args.alphas), sigma0s=_float_list(args.sigma0s),
        deltas=_float_list(args.deltas),
        strategies=strategies, cohort_size=args.cohort,
        task=args.task, lm_d_model=args.lm_d_model,
        lm_layers=args.lm_layers, lm_seq=args.lm_seq)
    store = ResultsStore(args.out)
    print("sweep,scheme,algo,strategy,hparams,seeds,test_acc_mean,"
          "test_acc_ci95,train_acc_mean", flush=True)
    for cell in run_sweep(spec, store=store, suite=args.suite):
        s = cell.summary()
        hp = ";".join(f"{k}={v:g}" for k, v in sorted(cell.hparams.items()))
        print(f"sweep,{cell.scheme},{cell.algo},{cell.strategy},{hp},"
              f"{len(cell.seeds)},"
              f"{s['test_acc']['mean']:.4f},{s['test_acc']['ci95']:.4f},"
              f"{s['train_acc']['mean']:.4f}", flush=True)
    print(f"# results appended to {store.path}", flush=True)


if __name__ == "__main__":
    main()
