"""``python -m repro.experiments`` — alias for the sweep CLI (avoids the
runpy double-import warning ``-m repro.experiments.sweep`` prints)."""
from repro.experiments.sweep import main

if __name__ == "__main__":
    main()
