"""The synthetic stand-in task used by the paper-table sweeps.

The image datasets of the paper (SVHN/CIFAR-10/CINIC-10) are unavailable
offline; every quantitative suite runs the same protocol (Dirichlet(alpha)
non-IID split, Eq.-9 heterogeneous p_i, s local steps, decaying LR) on the
10-class Gaussian task from ``repro.data.synthetic`` with a 2-layer MLP.

A ``ClassificationTask`` bundles everything the sweep engine vmaps over a
seed axis: the loss, a per-seed ``init_params(key)``, device-side train/test
accuracy evals (they return traced scalars, NOT floats, so they compose with
``vmap``), and the shared device-resident ``DataSource``. The dataset itself
is shared across seeds — per-seed randomness enters through PRNG keys and the
per-seed Eq.-9 ``p_base`` draw, matching the paper's seed protocol.

``ClassificationTask`` captures the dataset and its Dirichlet(alpha)
partition as jit constants, which is fine for a fixed alpha but forces a full
task + compile rebuild per swept alpha. ``TracedClassificationTask``
(``make_traced_classification_task``) is the traced-everything variant the
batched sweep core runs on: the dataset arrays enter the compiled program as
the ``shared`` traced input, the partition travels per hyperparameter point
in ``ds_state`` (``partition(alpha)`` is host-side numpy, identical to the
constant task's split for equal alpha), and the evals take ``(params,
shared)`` so they stay traced too.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (
    classification_source,
    dirichlet_partition,
    make_classification_data,
    traced_classification_source,
)
from repro.data.sources import DataSource, traced_lm_source


def mlp_init(key, dim=32, classes=10, hidden=64):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * hidden ** -0.5,
        "b2": jnp.zeros(classes),
    }


def mlp_loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def mlp_accuracy(params, x, y):
    """Traced accuracy (use ``float(...)`` at the call site for host scalars)."""
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return (jnp.argmax(logits, -1) == y).mean()


@dataclass(frozen=True)
class ClassificationTask:
    loss_fn: Callable[..., Any]
    init_params: Callable[..., Any]     # (key) -> params, vmap-able
    eval_test: Callable[..., Any]       # (params) -> traced scalar accuracy
    eval_train: Callable[..., Any]      # (params) -> traced scalar accuracy
    source: DataSource
    meta: Dict[str, Any] = field(default_factory=dict)


def make_classification_task(*, data_seed=0, num_clients=100, dim=32,
                             classes=10, hidden=64, n_per_class=600, sep=3.0,
                             n_train=5000, alpha=0.1, per_client=64,
                             local_steps=5, batch_size=32) -> ClassificationTask:
    """Build the shared dataset + partition + source + eval closures.

    ``alpha`` shapes the Dirichlet partition (and hence the jit-constant index
    table inside the source), so tasks — unlike Eq.-9 knobs — are rebuilt per
    distinct ``alpha``.
    """
    rng = np.random.default_rng(data_seed)
    x_all, y_all = make_classification_data(data_seed, dim=dim,
                                            num_classes=classes,
                                            n_per_class=n_per_class, sep=sep)
    x, y = x_all[:n_train], y_all[:n_train]
    xt, yt = x_all[n_train:], y_all[n_train:]
    idx, _ = dirichlet_partition(rng, y, num_clients, alpha=alpha,
                                 per_client=per_client)
    source = classification_source(x, y, idx, local_steps=local_steps,
                                   batch_size=batch_size)
    x_j, y_j = jnp.asarray(x), jnp.asarray(y)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)

    def init_params(key):
        return mlp_init(key, dim=dim, classes=classes, hidden=hidden)

    return ClassificationTask(
        loss_fn=mlp_loss,
        init_params=init_params,
        eval_test=lambda params: mlp_accuracy(params, xt_j, yt_j),
        eval_train=lambda params: mlp_accuracy(params, x_j, y_j),
        source=source,
        meta={"dataset": "gaussian10", "data_seed": data_seed, "dim": dim,
              "classes": classes, "hidden": hidden, "n_train": n_train,
              "n_test": int(len(x_all) - n_train), "alpha": alpha,
              "num_clients": num_clients, "per_client": per_client,
              "local_steps": local_steps, "batch_size": batch_size},
    )


def with_label_noise(shared: Dict[str, Any], key, frac: float = 0.1,
                     classes: int = None) -> Dict[str, Any]:
    """Same-shape label-noise variant of a task's ``shared`` dataset: a
    Bernoulli(``frac``) subset of the train labels is shifted to the next
    class (cyclically). Because the dataset arrays are *traced* inputs of the
    batched sweep runner, the variant rides an existing compiled program —
    no new task, no new partition, no recompile (the ROADMAP "traced dataset
    swaps" path; pinned by ``tests/test_traced_axes.py``)."""
    y = shared["y"]
    c = classes if classes is not None else int(y.max()) + 1
    flip = jax.random.uniform(key, y.shape) < frac
    return dict(shared, y=jnp.where(flip, (y + 1) % c, y))


@dataclass(frozen=True)
class TracedClassificationTask:
    """Alpha-free task bundle for the batched sweep core.

    ``shared`` is the dataset pytree the runner threads through its compiled
    programs as an *unbatched traced input* (``{"x", "y", "xt", "yt"}``);
    ``partition(alpha)`` produces one hyperparameter point's per-client index
    table (host-side numpy, cache the result per alpha); ``source_factory``
    and the evals are meant to be called inside the trace on the traced
    ``shared``.
    """

    loss_fn: Callable[..., Any]
    init_params: Callable[..., Any]      # (key) -> params, vmap-able
    source_factory: Callable[..., DataSource]  # (shared) -> traced DataSource
    eval_test: Callable[..., Any]        # (params, shared) -> traced scalar
    eval_train: Callable[..., Any]       # (params, shared) -> traced scalar
    partition: Callable[[float], np.ndarray]   # (alpha) -> idx [m, per_client]
    shared: Dict[str, Any]
    meta: Dict[str, Any] = field(default_factory=dict)


def make_traced_classification_task(*, data_seed=0, num_clients=100, dim=32,
                                    classes=10, hidden=64, n_per_class=600,
                                    sep=3.0, n_train=5000, per_client=64,
                                    local_steps=5,
                                    batch_size=32) -> TracedClassificationTask:
    """Traced-everything variant of ``make_classification_task``.

    No ``alpha`` argument: the partition is a per-hyperparameter-point input
    (``partition(alpha)``), drawn from a fresh ``default_rng(data_seed)`` so
    it is bit-identical to the constant task's split at the same alpha.
    """
    x_all, y_all = make_classification_data(data_seed, dim=dim,
                                            num_classes=classes,
                                            n_per_class=n_per_class, sep=sep)
    x, y = x_all[:n_train], y_all[:n_train]
    xt, yt = x_all[n_train:], y_all[n_train:]
    shared = {"x": jnp.asarray(x), "y": jnp.asarray(y),
              "xt": jnp.asarray(xt), "yt": jnp.asarray(yt)}

    def partition(alpha: float) -> np.ndarray:
        rng = np.random.default_rng(data_seed)
        idx, _ = dirichlet_partition(rng, y, num_clients, alpha=alpha,
                                     per_client=per_client)
        return idx

    def init_params(key):
        return mlp_init(key, dim=dim, classes=classes, hidden=hidden)

    return TracedClassificationTask(
        loss_fn=mlp_loss,
        init_params=init_params,
        source_factory=lambda sh: traced_classification_source(
            sh, local_steps=local_steps, batch_size=batch_size),
        eval_test=lambda params, sh: mlp_accuracy(params, sh["xt"], sh["yt"]),
        eval_train=lambda params, sh: mlp_accuracy(params, sh["x"], sh["y"]),
        partition=partition,
        shared=shared,
        meta={"dataset": "gaussian10", "data_seed": data_seed, "dim": dim,
              "classes": classes, "hidden": hidden, "n_train": n_train,
              "n_test": int(len(x_all) - n_train),
              "num_clients": num_clients, "per_client": per_client,
              "local_steps": local_steps, "batch_size": batch_size},
    )


# Same field protocol as TracedClassificationTask — the sweep engine and
# grid.py treat both uniformly; the alias exists so call sites can say what
# workload they hold.
LMTask = TracedClassificationTask


def _styled_corpus(rng, *, n, seq_len, vocab, classes):
    """Synthetic byte-level-style corpus: ``n`` sequences of ``seq_len + 1``
    tokens (tokens/labels come from one slice), each tagged with one of
    ``classes`` styles. Style ``c`` draws uniformly from the half-vocab window
    ``[c*V//(2*classes), c*V//(2*classes) + V//2)`` — overlapping slices, so
    styles are statistically (not trivially) separable, mirroring the
    overlapping half-vocab protocol of ``lm_source``."""
    styles = rng.integers(0, classes, size=n).astype(np.int32)
    offsets = (styles * (vocab // 2)) // max(classes, 1)
    toks = offsets[:, None] + rng.integers(
        0, vocab // 2, size=(n, seq_len + 1))
    return toks.astype(np.int32), styles


def make_traced_lm_task(*, data_seed=0, num_clients=8, arch="smollm-135m",
                        d_model=64, layers=2, seq_len=32, classes=4,
                        n_seqs=256, n_test=64, per_client=16, local_steps=2,
                        batch_size=2) -> LMTask:
    """Reduced-config transformer LM as a first-class sweep workload.

    The model is ``reduced(get_config(arch), d_model, layers)`` forced to
    float32 (the sweep engine's bitwise contracts assume f32 accumulation);
    the corpus is a synthetic styled token set, Dirichlet-partitioned over
    per-sequence style labels exactly like the classification task is over
    class labels — so the non-IID severity knob ``alpha`` means the same
    thing. Everything is traced: the corpus rides ``shared`` ({"toks"
    [n, T+1], "toks_t" [n_test, T+1]}), the partition rides ``ds_state``,
    evals take ``(params, shared)`` and report next-token accuracy.
    """
    import dataclasses as _dc

    from repro.configs import get_config, reduced
    from repro.models import model as lm

    cfg = _dc.replace(reduced(get_config(arch), d_model=d_model,
                              layers=layers), dtype="float32")
    rng = np.random.default_rng(data_seed)
    toks, styles = _styled_corpus(rng, n=n_seqs, seq_len=seq_len,
                                  vocab=cfg.vocab_size, classes=classes)
    toks_t, _ = _styled_corpus(rng, n=n_test, seq_len=seq_len,
                               vocab=cfg.vocab_size, classes=classes)
    shared = {"toks": jnp.asarray(toks), "toks_t": jnp.asarray(toks_t)}
    ce_chunk = min(512, seq_len)

    def partition(alpha: float) -> np.ndarray:
        prng = np.random.default_rng(data_seed)
        idx, _ = dirichlet_partition(prng, styles, num_clients, alpha=alpha,
                                     per_client=per_client)
        return idx

    def lm_loss(params, batch):
        return lm.loss_fn(params, cfg, batch, remat=False, ce_chunk=ce_chunk)

    def next_token_accuracy(params, seqs):
        logits, _ = lm.forward(params, cfg, seqs[:, :-1])
        return (jnp.argmax(logits, -1) == seqs[:, 1:]).mean()

    return LMTask(
        loss_fn=lm_loss,
        init_params=lambda key: lm.init_params(key, cfg),
        source_factory=lambda sh: traced_lm_source(
            sh, local_steps=local_steps, batch_size=batch_size),
        eval_test=lambda params, sh: next_token_accuracy(params, sh["toks_t"]),
        eval_train=lambda params, sh: next_token_accuracy(params, sh["toks"]),
        partition=partition,
        shared=shared,
        meta={"dataset": "styled-lm", "data_seed": data_seed, "arch": arch,
              "d_model": d_model, "layers": layers, "seq_len": seq_len,
              "classes": classes, "vocab": cfg.vocab_size,
              "n_train": n_seqs, "n_test": n_test,
              "num_clients": num_clients, "per_client": per_client,
              "local_steps": local_steps, "batch_size": batch_size},
    )
