"""Multi-device execution of the batched sweep runner.

``make_batched_run_rounds`` runs all B = algos x points x seeds trajectories
of one (algorithm-family, scheme) cell as one compiled program over a leading
batch axis.
Trajectories never exchange data — every reduction in the program is within a
single trajectory — so that axis is embarrassingly parallel and this module
splits it across devices with GSPMD:

- a 1-D ``("batch",)`` :class:`~jax.sharding.Mesh` over the participating
  devices (``repro.launch.mesh.make_batch_mesh``);
- ``CellBatch.keys / p_base / hparams / data / algo_id`` placed with their
  leading axis sharded over ``"batch"`` and ``shared`` (the dataset)
  replicated, one full copy per device (``repro.sharding.specs``);
- B padded up to a multiple of the device count by repeating the last real
  trajectory. Padding rows are full, finite simulations (never NaN inputs
  that could poison a compiler-introduced collective); their results are
  sliced away ON THE HOST before anything reaches a ``CellResult`` or a
  ``ResultsStore`` row.

Because the runner's jitted stages infer shardings from their committed
inputs, the SAME runner object (and hence the executor's structure-only
runner cache) serves both paths; the sharded call just compiles a second,
partitioned executable. Per-trajectory results are bit-for-bit equal to the
single-device path — each device executes the same per-trajectory program on
its slice — which ``tests/test_sharded_sweep.py`` asserts on 8 forced host
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8``), including
a B not divisible by the device count.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.experiments.sweep import CellBatch
from repro.launch.mesh import make_batch_mesh
from repro.sharding.specs import leading_axis_sharding, replicated_sharding

Mesh = jax.sharding.Mesh

# run_cell_batch's default: shard automatically when >1 device is visible.
AUTO = "auto"


def resolve_batch_mesh(mesh: Union[str, Mesh, None] = AUTO,
                       devices: Optional[Sequence] = None) -> Optional[Mesh]:
    """The mesh a sweep call should execute on, or None for the plain
    single-device path.

    - ``mesh`` a :class:`Mesh`: used as given (must carry a ``"batch"`` axis).
    - ``mesh=None``: force the single-device path regardless of ``devices``.
    - ``mesh="auto"`` (default): a ``("batch",)`` mesh over ``devices`` when
      given (even a single device — an explicit list opts in to the sharded
      wrapper), else over all visible devices when more than one is up.
    """
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        if "batch" not in mesh.axis_names:
            raise ValueError(
                f"sweep mesh needs a 'batch' axis; got {mesh.axis_names}")
        return mesh
    if mesh != AUTO:
        raise ValueError(f"mesh must be a Mesh, None, or 'auto'; got {mesh!r}")
    if devices is not None:
        return make_batch_mesh(devices)
    return make_batch_mesh() if len(jax.devices()) > 1 else None


def pad_batch(batch: CellBatch, multiple: int) -> tuple:
    """Pad the leading [B] axis of the batched fields up to a multiple of
    ``multiple`` by repeating the last trajectory; ``shared`` is untouched.
    Returns ``(padded, B)`` with B the real (pre-padding) batch size, so the
    caller can slice the padding back off the results."""
    B = batch.batch_size
    pad = (-B) % multiple
    if pad == 0:
        return batch, B

    def _pad(x):
        return jnp.concatenate([x, jnp.repeat(x[-1:], pad, axis=0)])

    keys, p_base, hparams, data, algo_id = jax.tree.map(
        _pad, (batch.keys, batch.p_base, batch.hparams, batch.data,
               batch.algo_id))
    return CellBatch(keys=keys, p_base=p_base, hparams=hparams, data=data,
                     shared=batch.shared, algo_id=algo_id), B


def shard_batch(batch: CellBatch, mesh: Mesh) -> CellBatch:
    """Commit the batch to ``mesh``: [B]-leading fields split over the
    ``"batch"`` axis, ``shared`` replicated (on a 2-D mesh that means each
    trajectory's inputs are replicated across its ``"model"`` devices). The
    batch size must already be a multiple of the mesh's batch axis (see
    ``pad_batch``)."""
    n = mesh.shape["batch"]
    if batch.batch_size % n:
        raise ValueError(
            f"batch size {batch.batch_size} not divisible by the mesh's "
            f"batch axis ({n}); pad_batch first")
    split = leading_axis_sharding(mesh)
    repl = replicated_sharding(mesh)
    keys, p_base, hparams, data, algo_id = jax.tree.map(
        lambda x: jax.device_put(x, split),
        (batch.keys, batch.p_base, batch.hparams, batch.data, batch.algo_id))
    shared = jax.tree.map(lambda x: jax.device_put(x, repl), batch.shared)
    return CellBatch(keys=keys, p_base=p_base, hparams=hparams, data=data,
                     shared=shared, algo_id=algo_id)


def run_sharded(runner, batch: CellBatch, mesh: Mesh):
    """Run one cell batch on ``mesh``: pad, shard, execute, and drop the
    padding rows from every output leaf (host-side slice — padding must never
    leak into downstream results). Same ``(states, out)`` contract as calling
    ``runner(batch)`` directly."""
    padded, B = pad_batch(batch, mesh.shape["batch"])
    states, out = runner(shard_batch(padded, mesh))
    if padded.batch_size == B:
        return states, out
    return jax.tree.map(lambda x: x[:B], (states, out))


def run_sharded_2d(runner, batch: CellBatch, mesh: Mesh, *,
                   activation_spec=None):
    """Run one cell batch on a 2-D ``("batch", "model")`` mesh
    (``repro.launch.mesh.make_2d_mesh``): trajectories split over
    ``"batch"``, each trajectory's parameters/optimizer state split over
    ``"model"`` by the runner's internal constraints — the runner must have
    been built with ``make_batched_run_rounds(..., shard_mesh=mesh)`` (the
    in-program placement lives in its trace, not in the input shardings).

    ``activation_spec``: optional PartitionSpec for the LM residual stream,
    installed for the duration of the call via the ``repro.sharding.specs``
    context hooks so ``maybe_constrain`` inside the model forward becomes
    live (Megatron-style sequence parallelism, e.g. ``P(None, "model",
    None)``). The default None leaves activations to GSPMD — the bitwise
    contract of the CPU tests assumes the default.

    Same pad / execute / host-side-slice contract as ``run_sharded``.
    """
    missing = {"batch", "model"} - set(mesh.axis_names)
    if missing:
        raise ValueError(
            f"run_sharded_2d needs a ('batch', 'model') mesh; "
            f"{mesh.axis_names} lacks {sorted(missing)}")
    rmesh = getattr(runner, "shard_mesh", None)
    if rmesh is None or rmesh != mesh:
        raise ValueError(
            "runner was not built for this mesh — pass shard_mesh=mesh to "
            "make_batched_run_rounds (got runner.shard_mesh="
            f"{rmesh})")
    padded, B = pad_batch(batch, mesh.shape["batch"])
    sharded = shard_batch(padded, mesh)
    if activation_spec is not None:
        from repro.sharding.specs import activation_sharding, set_mesh
        set_mesh(mesh)
        try:
            with activation_sharding(activation_spec):
                states, out = runner(sharded)
        finally:
            set_mesh(None)
    else:
        states, out = runner(sharded)
    if padded.batch_size == B:
        return states, out
    return jax.tree.map(lambda x: x[:B], (states, out))
