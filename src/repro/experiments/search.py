"""Adaptive hyperparameter search over the sweep engine: successive halving
(ASHA-style) with elastic re-batching and host/device overlap.

The exhaustive grid burns a full ``rounds`` budget on every hyperparameter
point, including the ones that are visibly losing after a handful of evals.
This driver runs a candidate population in *rung-sized segments* on the
resumable scan-segment runner (``make_batched_run_rounds(carry_out=True)``
via ``grid.segment_runner_for``): each wave scans ``rung_rounds`` rounds for
every live candidate, ranks points on the in-scan eval fired at the segment
end, and keeps the top ``1/eta`` of each budget level; the rest are pruned
with their truncated trajectories persisted. Survivors' ``(FedState,
ds_state)`` carries are **elastically re-packed** into full-width
``CellBatch``es — the compiled program never runs half-empty — and because
the runner-cache key is structure-only, every re-pack, every unseen
hyperparameter value, and every refilled fresh candidate rides ONE compiled
(init, scan) pair per (family, scheme): zero new jit entries across the
whole search (``tests/test_search.py`` pins the counter).

Host/device overlap contract: at a prune point the host blocks ONLY on the
tiny ``[B]`` last-eval column of each batch (the ranking signal). The next
wave is packed and dispatched immediately; only then are the finished wave's
full metric trajectories pulled to the host and the stopped candidates' rows
persisted to the ``ResultsStore`` — the heavy result slicing runs while the
device is already scanning the next rung (the PR-4 loose end). In
``carry_out`` mode the carry is donated on non-CPU backends, so chaining
segments updates the [B]-state in place.

Rung math: a candidate's budget after surviving r waves is ``r *
rung_rounds``; ``base.rounds`` is the budget cap (``rung_rounds`` must
divide it), so a sole survivor keeps riding ``rung_rounds``-sized segments
until it graduates with the same total budget the exhaustive grid would
have spent on every point. With ``refill=True``, batch slots freed by
pruning are filled with freshly sampled candidates (up to
``max_candidates``) instead of duplicate padding; candidates are only
ranked against others at the SAME budget level, so a fresh level-0 filler
never knocks out a level-3 survivor on an unfair comparison.

CLI::

    PYTHONPATH=src python -m repro.experiments.search \
        --algo fedpbc --scheme bernoulli_tv --seeds 0,1 --clients 32 \
        --rounds 60 --rung-rounds 10 --candidates 16 --batch-points 8 \
        --space lr=log:0.01:0.5 gamma=uniform:0.1:0.9 \
        --out benchmarks/out/search
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algorithms import algo_family
from repro.experiments.grid import (
    HPARAM_FIELDS,
    SweepSpec,
    get_partition,
    get_traced_task,
    point_base_probs,
    segment_runner_for,
)
from repro.experiments.results import ResultsStore, summarize
from repro.experiments.sweep import CellBatch, stack_seed_keys
from repro.scale.buffer import SYNC

SAMPLER_KINDS = ("log", "uniform", "choice")


@dataclass(frozen=True)
class SearchSpec:
    """One adaptive search: the protocol (``base``), the rung schedule, and
    the candidate space.

    ``base`` pins everything a ``SweepSpec`` pins — algorithm, scheme,
    seeds, client count, dataset/model shape — except the hyperparameter
    axes, which the sampler replaces: ``base.rounds`` is the per-candidate
    budget cap, ``base.eval_every`` is ignored (the eval cadence is
    ``rung_rounds``, one in-scan eval per segment). Exactly one algorithm,
    one scheme, and the synchronous strategy are supported per search (the
    cohort path composes; run several searches for several cells).

    ``space`` entries are ``(field, (kind, *args))`` with ``field`` in
    ``HPARAM_FIELDS`` and ``kind`` one of ``log`` (log-uniform in
    ``(lo, hi)``), ``uniform``, or ``choice`` (uniform over the listed
    values); unsampled fields keep ``base``'s scalar. ``points`` instead
    passes an explicit candidate pool (e.g. a grid, for an
    early-stopping-vs-exhaustive comparison); missing fields again default
    to ``base``'s scalars.
    """

    base: SweepSpec
    rung_rounds: int
    eta: int = 2
    num_candidates: int = 8
    # points per compiled batch (the elastic re-pack width W; batch width is
    # W * len(seeds) trajectories). None: the whole population in one batch.
    batch_points: Optional[int] = None
    space: Tuple[Tuple[str, tuple], ...] = ()
    points: Optional[Tuple[Dict[str, float], ...]] = None
    # fill partial batches with freshly sampled level-0 candidates (free
    # exploration in slots that would otherwise be duplicate padding)
    refill: bool = False
    max_candidates: Optional[int] = None    # total sampling cap for refill
    # stop the whole search once any candidate's point-mean eval reaches
    # this (time-to-target mode); None runs every survivor to the budget cap
    target: Optional[float] = None
    search_seed: int = 0

    def __post_init__(self):
        base = self.base
        for axis, n in (("algorithms", len(base.algorithms)),
                        ("schemes", len(base.schemes))):
            if n != 1:
                raise ValueError(
                    f"SearchSpec.base.{axis} has {n} entries; a search "
                    f"drives one (algorithm, scheme) cell — run one search "
                    f"per cell")
        if base.strategies != (SYNC,):
            raise ValueError(
                "SearchSpec.base.strategies must be (SYNC,): the controller "
                "ranks on the synchronous eval contract")
        hp_axes = [f for f in HPARAM_FIELDS if getattr(base, f + "s")]
        if hp_axes:
            raise ValueError(
                f"SearchSpec.base carries swept axes {hp_axes}; the search "
                f"samples its own points — pass them via space= or points=")
        if self.rung_rounds < 1:
            raise ValueError(f"rung_rounds={self.rung_rounds} must be >= 1")
        if base.rounds % self.rung_rounds:
            raise ValueError(
                f"rung_rounds={self.rung_rounds} must divide the budget cap "
                f"base.rounds={base.rounds} (segments are same-length by "
                f"construction — one scan compile)")
        if self.eta < 2:
            raise ValueError(f"eta={self.eta} must be >= 2")
        if self.points is not None:
            if not self.points:
                raise ValueError("points= is empty; give at least one "
                                 "candidate")
            for pt in self.points:
                bad = sorted(set(pt) - set(HPARAM_FIELDS))
                if bad:
                    raise ValueError(
                        f"points entry has unknown fields {bad}; "
                        f"hyperparameter fields are {HPARAM_FIELDS}")
        elif self.num_candidates < 1:
            raise ValueError(
                f"num_candidates={self.num_candidates} must be >= 1")
        for name, dist in self.space:
            if name not in HPARAM_FIELDS:
                raise ValueError(
                    f"space field {name!r} is not a hyperparameter; "
                    f"expected one of {HPARAM_FIELDS}")
            kind = dist[0] if dist else None
            if kind not in SAMPLER_KINDS:
                raise ValueError(
                    f"space[{name!r}] kind {kind!r}; expected one of "
                    f"{SAMPLER_KINDS}")
            if kind in ("log", "uniform"):
                if len(dist) != 3 or not dist[1] < dist[2]:
                    raise ValueError(
                        f"space[{name!r}]=({kind}, lo, hi) needs lo < hi, "
                        f"got {dist[1:]}")
                if kind == "log" and dist[1] <= 0:
                    raise ValueError(
                        f"space[{name!r}] log-sampling needs lo > 0, got "
                        f"{dist[1]}")
            elif len(dist) < 2 or not dist[1]:
                raise ValueError(
                    f"space[{name!r}]=('choice', (v, ...)) needs at least "
                    f"one value")
        if self.batch_points is not None and self.batch_points < 1:
            raise ValueError(
                f"batch_points={self.batch_points} must be >= 1")
        if self.refill and not self.space:
            raise ValueError(
                "refill=True needs a space= to sample fresh candidates from")
        pop = len(self.points) if self.points is not None \
            else self.num_candidates
        if self.max_candidates is not None and self.max_candidates < pop:
            raise ValueError(
                f"max_candidates={self.max_candidates} is below the initial "
                f"population {pop}")

    @property
    def population(self) -> int:
        return len(self.points) if self.points is not None \
            else self.num_candidates

    @property
    def width(self) -> int:
        """Points per compiled batch — the fixed pack width W."""
        return min(self.batch_points or self.population, self.population)

    @property
    def max_level(self) -> int:
        """Segments to the budget cap (a candidate's level is its count of
        completed segments; budget = level * rung_rounds)."""
        return self.base.rounds // self.rung_rounds


def sample_point(rng: np.random.Generator,
                 search: SearchSpec) -> Dict[str, float]:
    """Draw one candidate from ``search.space`` (unsampled fields keep the
    base spec's scalar knobs)."""
    pt = {f: float(getattr(search.base, f)) for f in HPARAM_FIELDS}
    for name, dist in search.space:
        kind = dist[0]
        if kind == "log":
            lo, hi = float(dist[1]), float(dist[2])
            pt[name] = float(np.exp(rng.uniform(np.log(lo), np.log(hi))))
        elif kind == "uniform":
            pt[name] = float(rng.uniform(float(dist[1]), float(dist[2])))
        else:   # choice
            vals = dist[1]
            pt[name] = float(vals[int(rng.integers(len(vals)))])
    return pt


@dataclass
class Candidate:
    """Host-side bookkeeping for one search candidate (a hyperparameter
    point across all seeds)."""

    cid: int
    point: Dict[str, float]
    level: int = 0                  # completed rung_rounds-sized segments
    rung: int = 0                   # prune points survived
    status: str = "alive"           # alive | pruned | finished | stopped
    evals: List[float] = field(default_factory=list)    # point-mean, per seg
    test_acc: List[np.ndarray] = field(default_factory=list)    # [S] per seg
    metrics: Dict[str, List[np.ndarray]] = field(default_factory=dict)
    pool_point: int = -1            # point index into the last wave's carry
    record_id: Optional[int] = None

    @property
    def last_eval(self) -> float:
        return self.evals[-1] if self.evals else float("-inf")


@dataclass
class SearchOutcome:
    """What one ``run_search`` spent and found."""

    candidates: List[Candidate]
    waves: int
    # trajectory-rounds dispatched: Sum over batches of W * S * rung_rounds
    # (seeds and duplicate-padding slots included — they burn device work)
    total_device_rounds: int
    # per wave: cumulative device rounds + the best point-mean eval so far
    wave_log: List[Dict[str, float]]
    target_hit: bool
    compile_entries: Dict[str, Optional[int]]

    @property
    def best(self) -> Candidate:
        return max((c for c in self.candidates if c.evals),
                   key=lambda c: (c.last_eval, c.level))

    def device_rounds_to(self, target: float) -> Optional[int]:
        """Cumulative device rounds at the first wave whose best eval
        reached ``target`` (None: never reached)."""
        for entry in self.wave_log:
            if entry["best_eval"] >= target - 1e-9:
                return int(entry["device_rounds"])
        return None


def run_search(search: SearchSpec, *, store: Optional[ResultsStore] = None,
               suite: str = "search",
               metric_keys=("loss", "num_active"),
               verbose: bool = False) -> SearchOutcome:
    """Run one successive-halving search; optionally persist one store row
    per candidate (truncated trajectories for pruned points, full-budget
    ones for finished points), each stamped with ``search`` provenance
    (rung, budget_rounds, status) that ``results.cell_key`` folds into the
    row's identity."""
    spec = search.base
    algo, scheme = spec.algorithms[0], spec.schemes[0]
    task = get_traced_task(spec)
    fed = spec.cell_config(algo, scheme)
    family = algo_family(algo)
    algo_idx = family.index(algo)
    runner = segment_runner_for(spec, algo, scheme,
                                segment_rounds=search.rung_rounds,
                                metric_keys=metric_keys)
    seg = search.rung_rounds
    S = len(spec.seeds)
    W = search.width
    max_level = search.max_level
    rng = np.random.default_rng(search.search_seed)
    seed_bundle = stack_seed_keys(spec.seeds)

    defaults = {f: float(getattr(spec, f)) for f in HPARAM_FIELDS}
    if search.points is not None:
        pool = [dict(defaults, **pt) for pt in search.points]
    else:
        pool = [sample_point(rng, search)
                for _ in range(search.num_candidates)]
    cap = search.max_candidates if search.max_candidates is not None \
        else len(pool)
    candidates = [Candidate(cid=i, point=pt) for i, pt in enumerate(pool)]

    # the Eq.-9 draw depends only on (alpha, sigma0, delta); memoize across
    # waves so re-packs never redo host-side sampling
    probs_memo: Dict[tuple, jnp.ndarray] = {}

    def probs(pt):
        k = (pt["alpha"], pt["sigma0"], pt["delta"])
        if k not in probs_memo:
            probs_memo[k] = point_base_probs(spec, pt)
        return probs_memo[k]

    def build_batch(pts: List[Dict[str, float]]) -> CellBatch:
        keys = jax.tree.map(lambda k: jnp.concatenate([k] * len(pts)),
                            seed_bundle)
        p_base = jnp.concatenate([probs(pt) for pt in pts])
        lr = jnp.asarray([pt["lr"] for pt in pts for _ in range(S)],
                         jnp.float32)
        gamma = jnp.asarray([pt["gamma"] for pt in pts for _ in range(S)],
                            jnp.float32)
        idx = jnp.asarray(np.stack([get_partition(spec, pt["alpha"])
                                    for pt in pts for _ in range(S)]))
        hparams = {"lr": lr, "gamma": gamma,
                   "period": jnp.full((lr.shape[0],), float(fed.period),
                                      jnp.float32)}
        return CellBatch(keys=keys, p_base=p_base, hparams=hparams,
                         data={"idx": idx}, shared=task.shared,
                         algo_id=jnp.full((lr.shape[0],), algo_idx,
                                          jnp.int32))

    prev_pool = None                # concatenated last-wave carry [P*W*S]
    total_rounds = 0
    wave_log: List[Dict[str, float]] = []
    target_hit = False
    waves = 0

    def dispatch_wave(alive: List[Candidate]):
        """Pack the live population into full-width batches (survivors
        carried, level-0 slots freshly inited, leftover slots refilled or
        duplicate-padded) and dispatch every segment. Returns the list of
        ``(occupants, n_real, carry, out)`` async handles."""
        nonlocal total_rounds
        # deterministic pack order: deepest budget first (survivors stay
        # contiguous across re-packs), best-eval-first within a level
        alive = sorted(alive, key=lambda c: (-c.level, -c.last_eval, c.cid))
        groups = [alive[i:i + W] for i in range(0, len(alive), W)]
        last = groups[-1]
        while len(last) < W and search.refill and search.space \
                and len(candidates) < cap:
            c = Candidate(cid=len(candidates),
                          point=sample_point(rng, search))
            candidates.append(c)
            last.append(c)
        handles = []
        for occ in groups:
            n_real = len(occ)
            # duplicate-pad to full width; padded slots replicate occupant
            # 0 (its carry AND its batch columns) and are dropped on read
            occ = occ + [occ[0]] * (W - n_real) if n_real < W else occ
            batch = build_batch([c.point for c in occ])
            cont = np.array([c.level > 0 for c in occ])
            rows = np.zeros((W * S,), np.int64)
            for j, c in enumerate(occ):
                if c.level > 0:
                    rows[j * S:(j + 1) * S] = c.pool_point * S + np.arange(S)
            if cont.all():
                carry = jax.tree.map(lambda x: x[jnp.asarray(rows)],
                                     prev_pool)
            elif not cont.any():
                carry = runner.init(batch)
            else:
                # mixed batch: survivors gather from the previous wave's
                # pool, fresh (refilled) slots take the batched init
                fresh = runner.init(batch)
                mask = jnp.asarray(np.repeat(cont, S))

                def pick(p, f):
                    sel = mask.reshape((mask.shape[0],)
                                       + (1,) * (f.ndim - 1))
                    return jnp.where(sel, p[jnp.asarray(rows)], f)

                carry = jax.tree.map(pick, prev_pool, fresh)
            # async dispatch; on donating backends the passed carry is
            # consumed here — `carry` is rebound to the segment's output
            carry, out = runner.step(carry, batch)
            total_rounds += W * S * seg
            handles.append((occ, n_real, carry, out))
        return handles

    def drain(handles) -> None:
        """Pull a finished wave's full metric trajectories to the host and
        persist every candidate the prune step stopped — the heavy
        transfers and store writes, running AFTER the next wave was
        dispatched (host work overlapped with device compute)."""
        for occ, n_real, _, out in handles:
            host = {k: np.asarray(v) for k, v in out["metrics"].items()}
            acc = np.asarray(out["evals"])
            for j, c in enumerate(occ[:n_real]):
                rows = slice(j * S, (j + 1) * S)
                c.test_acc.append(acc[rows, -1])
                for k in metric_keys:
                    c.metrics.setdefault(k, []).append(host[k][rows])
        if store is None:
            return
        for occ, n_real, _, _ in handles:
            for c in occ[:n_real]:
                if c.status != "alive" and c.record_id is None:
                    persist(c)

    def persist(c: Candidate) -> None:
        budget = c.level * seg
        ta = np.stack(c.test_acc, axis=1)           # [S, E]
        w = min(3, ta.shape[1])
        rec = {
            "suite": suite, "algo": algo, "scheme": scheme,
            "strategy": "sync", "seeds": list(spec.seeds),
            "rounds": budget, "eval_every": seg,
            "hparams": dict(c.point),
            "spec": dataclasses.asdict(dataclasses.replace(
                spec, rounds=budget, eval_every=seg)),
            "eval_rounds": [seg * (i + 1) for i in range(c.level)],
            "search": {"rung": c.rung, "budget_rounds": budget,
                       "status": c.status, "cid": c.cid,
                       "rung_rounds": seg, "eta": search.eta,
                       "population": search.population},
            "summary": {"test_acc": summarize(ta[:, -w:].mean(axis=1))},
        }
        arrays = {"test_acc": ta}
        for k in metric_keys:
            arrays[k] = np.concatenate(c.metrics[k], axis=1)
        c.record_id = store.append(rec, arrays=arrays)["record_id"]

    def prune(handles) -> None:
        """The prune point: block only on the [W] last-eval column of each
        batch, then decide who survives. Candidates are ranked within their
        own budget level; each level keeps ceil(n / eta)."""
        nonlocal target_hit
        advanced: List[Candidate] = []
        best_eval = float("-inf")
        for occ, n_real, _, out in handles:
            col = np.asarray(out["evals"][:, -1]).reshape(W, S).mean(axis=1)
            for j, c in enumerate(occ[:n_real]):
                c.level += 1
                c.evals.append(float(col[j]))
                advanced.append(c)
                best_eval = max(best_eval, c.evals[-1])
        wave_log.append({"device_rounds": total_rounds,
                         "best_eval": best_eval})
        for c in advanced:
            if c.level >= max_level:
                c.status = "finished"
        if search.target is not None and best_eval >= search.target - 1e-9:
            target_hit = True
            for c in advanced:
                if c.status == "alive":
                    c.status = "stopped"
            return
        by_level: Dict[int, List[Candidate]] = {}
        for c in advanced:
            if c.status == "alive":
                by_level.setdefault(c.level, []).append(c)
        for grp in by_level.values():
            grp.sort(key=lambda c: (-c.last_eval, c.cid))
            keep = -(-len(grp) // search.eta)       # ceil: never kill a level
            for c in grp[:keep]:
                c.rung += 1
            for c in grp[keep:]:
                c.status = "pruned"

    pending = None
    while True:
        alive = [c for c in candidates if c.status == "alive"]
        if not alive:
            break
        handles = dispatch_wave(alive)
        waves += 1
        if pending is not None:
            drain(pending)      # overlapped: device is scanning this wave
        prune(handles)
        if verbose:
            n_alive = sum(c.status == "alive" for c in candidates)
            print(f"# search wave {waves}: {len(handles)} batch(es), "
                  f"best_eval={wave_log[-1]['best_eval']:.4f}, "
                  f"alive={n_alive}, device_rounds={total_rounds}",
                  flush=True)
        # carries of this wave become the next re-pack's gather pool
        parts = [carry for _, _, carry, _ in handles]
        prev_pool = parts[0] if len(parts) == 1 else jax.tree.map(
            lambda *xs: jnp.concatenate(xs), *parts)
        for bi, (occ, n_real, _, _) in enumerate(handles):
            for j, c in enumerate(occ[:n_real]):
                c.pool_point = bi * W + j
        pending = handles
    if pending is not None:
        drain(pending)

    from repro.analysis.sanitize import cache_size
    entries = {"init": cache_size(runner.init_batch),
               "scan": cache_size(runner.scan_batch)}
    return SearchOutcome(candidates=candidates, waves=waves,
                         total_device_rounds=total_rounds,
                         wave_log=wave_log, target_hit=target_hit,
                         compile_entries=entries)


def _parse_space(items) -> Tuple[Tuple[str, tuple], ...]:
    """``name=kind:v1:v2[:v3...]`` -> SearchSpec.space entries (choice takes
    every listed value)."""
    out = []
    for item in items:
        try:
            name, rest = item.split("=", 1)
            kind, *vals = rest.split(":")
            vals = tuple(float(v) for v in vals)
        except ValueError:
            raise SystemExit(
                f"--space entry {item!r}; expected name=kind:v1:v2[:...] "
                f"(e.g. lr=log:0.01:0.5 or alpha=choice:0.1:1.0)")
        out.append((name, (kind, vals) if kind == "choice"
                    else (kind,) + vals))
    return tuple(out)


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Successive-halving (ASHA-style) hyperparameter search "
                    "over the batched sweep engine: candidates run in "
                    "rung-sized scan segments, losers are pruned on in-scan "
                    "evals, survivors are elastically re-packed into full "
                    "batches of ONE compiled program.")
    ap.add_argument("--algo", default="fedpbc")
    ap.add_argument("--scheme", default="bernoulli_ti")
    ap.add_argument("--seeds", default="0,1", help="comma list of ints")
    ap.add_argument("--rounds", type=int, default=40,
                    help="per-candidate budget cap (a multiple of "
                    "--rung-rounds)")
    ap.add_argument("--rung-rounds", type=int, default=10,
                    help="segment length: rounds between prune points")
    ap.add_argument("--eta", type=int, default=2,
                    help="keep top 1/eta of each budget level per prune")
    ap.add_argument("--candidates", type=int, default=8)
    ap.add_argument("--batch-points", type=int, default=None,
                    help="points per compiled batch (default: the whole "
                    "population)")
    ap.add_argument("--space", nargs="*", default=["lr=log:0.01:0.5"],
                    help="sampler per hyperparameter: name=kind:v1:v2[:...] "
                    "with kind in log|uniform|choice")
    ap.add_argument("--refill", action="store_true",
                    help="fill freed batch slots with fresh candidates")
    ap.add_argument("--max-candidates", type=int, default=None,
                    help="total sampling cap when refilling")
    ap.add_argument("--target", type=float, default=None,
                    help="stop the search once any candidate reaches this "
                    "test accuracy")
    ap.add_argument("--search-seed", type=int, default=0)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--out", default="benchmarks/out/search",
                    help="results-store directory (JSONL + npz)")
    ap.add_argument("--suite", default="search",
                    help="suite tag on the records")
    args = ap.parse_args(argv)

    base = SweepSpec(
        algorithms=(args.algo,), schemes=(args.scheme,),
        seeds=tuple(int(s) for s in args.seeds.split(",")),
        rounds=args.rounds, eval_every=args.rung_rounds,
        num_clients=args.clients, local_steps=args.local_steps)
    search = SearchSpec(
        base=base, rung_rounds=args.rung_rounds, eta=args.eta,
        num_candidates=args.candidates, batch_points=args.batch_points,
        space=_parse_space(args.space), refill=args.refill,
        max_candidates=args.max_candidates, target=args.target,
        search_seed=args.search_seed)
    store = ResultsStore(args.out)
    outcome = run_search(search, store=store, suite=args.suite, verbose=True)
    print("search,cid,status,rung,budget_rounds,hparams,last_eval",
          flush=True)
    for c in sorted(outcome.candidates, key=lambda c: -c.last_eval):
        hp = ";".join(f"{k}={v:g}" for k, v in sorted(c.point.items()))
        ev = f"{c.last_eval:.4f}" if c.evals else "nan"
        print(f"search,{c.cid},{c.status},{c.rung},"
              f"{c.level * args.rung_rounds},{hp},{ev}", flush=True)
    best = outcome.best
    grid_rounds = (len(outcome.candidates) * len(base.seeds) * args.rounds)
    print(f"# best cid={best.cid} eval={best.last_eval:.4f} | "
          f"device_rounds={outcome.total_device_rounds} "
          f"(exhaustive grid of the same pool: {grid_rounds}) | "
          f"waves={outcome.waves} target_hit={outcome.target_hit}",
          flush=True)
    print(f"# results appended to {store.path}", flush=True)


if __name__ == "__main__":
    main()
