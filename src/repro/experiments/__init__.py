"""Vectorized experiment sweeps: grid specs -> device-batched simulations.

- ``sweep``   — ``make_vmap_run_rounds``: S seeds of one (algo, scheme) cell
  as ONE compiled program (vmap over the seed axis), plus the sweep CLI.
- ``grid``    — ``SweepSpec`` grids, the executor, compile/task caches.
- ``results`` — append-only JSONL/npz results store with mean/CI summaries.
- ``tasks``   — the shared synthetic classification task the suites run on.
"""
from repro.experiments.grid import (
    ALGOS,
    SCHEMES,
    CellResult,
    SweepSpec,
    run_cell,
    run_sweep,
)
from repro.experiments.results import ResultsStore, git_sha, summarize
from repro.experiments.sweep import (
    eval_rounds,
    make_vmap_run_rounds,
    seed_keys,
    stack_seed_keys,
)
from repro.experiments.tasks import (
    ClassificationTask,
    make_classification_task,
    mlp_accuracy,
    mlp_init,
    mlp_loss,
)

__all__ = [
    "ALGOS",
    "SCHEMES",
    "CellResult",
    "SweepSpec",
    "run_cell",
    "run_sweep",
    "ResultsStore",
    "git_sha",
    "summarize",
    "eval_rounds",
    "make_vmap_run_rounds",
    "seed_keys",
    "stack_seed_keys",
    "ClassificationTask",
    "make_classification_task",
    "mlp_accuracy",
    "mlp_init",
    "mlp_loss",
]
