"""Vectorized experiment sweeps: grid specs -> device-batched simulations.

- ``sweep``   — ``make_batched_run_rounds``: all (algorithm x hyperparameter
  point x seed) trajectories of one (algorithm-family, scheme) cell as ONE
  compiled program over a traced ``CellBatch`` (the algorithm selected per
  trajectory by a traced ``algo_id`` into an ``AlgorithmSpec`` table);
  ``make_vmap_run_rounds`` is the single-point seed-axis wrapper; plus the
  sweep CLI.
- ``grid``    — ``SweepSpec`` grids (with ``lrs``/``gammas``/``alphas``/
  ``sigma0s``/``deltas`` axes and algorithm-family batching), the executor,
  structure-only compile caches.
- ``shard``   — multi-device execution of the batched runner: the flattened
  (algo x point x seed) batch axis sharded over a ``("batch",)`` mesh,
  ``shared`` replicated, B padded to a device multiple (padding dropped on
  the host).
- ``search``  — ``run_search``: successive-halving (ASHA-style) adaptive
  hyperparameter search over the sweep engine — rung-sized scan segments on
  the resumable ``carry_out`` runner, elastic re-batching of survivors into
  full ``CellBatch``es (zero new jit entries), host-side pruning overlapped
  with device compute; plus the search CLI.
- ``results`` — append-only JSONL/npz results store with mean/CI summaries,
  cross-store ``merge`` + CLI.
- ``plots``   — figure-style curve CSV exports straight from a store.
- ``tasks``   — the shared synthetic task (constant and traced variants).
"""
from repro.experiments.grid import (
    ALGOS,
    HPARAM_FIELDS,
    SCHEMES,
    CellResult,
    SweepSpec,
    run_cell,
    run_cell_batch,
    run_sweep,
)
from repro.experiments.results import ResultsStore, git_sha, summarize
from repro.experiments.search import (
    SearchOutcome,
    SearchSpec,
    run_search,
    sample_point,
)
from repro.experiments.shard import (
    pad_batch,
    resolve_batch_mesh,
    run_sharded,
    shard_batch,
)
from repro.experiments.sweep import (
    CellBatch,
    eval_rounds,
    make_batched_run_rounds,
    make_vmap_run_rounds,
    seed_keys,
    stack_seed_keys,
)
from repro.experiments.tasks import (
    ClassificationTask,
    TracedClassificationTask,
    make_classification_task,
    make_traced_classification_task,
    mlp_accuracy,
    mlp_init,
    mlp_loss,
    with_label_noise,
)

__all__ = [
    "ALGOS",
    "HPARAM_FIELDS",
    "SCHEMES",
    "CellResult",
    "SweepSpec",
    "run_cell",
    "run_cell_batch",
    "run_sweep",
    "ResultsStore",
    "git_sha",
    "summarize",
    "SearchOutcome",
    "SearchSpec",
    "run_search",
    "sample_point",
    "pad_batch",
    "resolve_batch_mesh",
    "run_sharded",
    "shard_batch",
    "CellBatch",
    "eval_rounds",
    "make_batched_run_rounds",
    "make_vmap_run_rounds",
    "seed_keys",
    "stack_seed_keys",
    "ClassificationTask",
    "TracedClassificationTask",
    "make_classification_task",
    "make_traced_classification_task",
    "mlp_accuracy",
    "mlp_init",
    "mlp_loss",
    "with_label_noise",
]
