"""Declarative sweep grids: ``SweepSpec`` -> batched device simulations.

A paper evaluation is a grid of ``(algorithm x unreliable-link scheme x
hyperparameter point x seed)`` cells. The executor walks only the *algorithm
family x scheme* axes in Python — distinct families / schemes carry distinct
``algo_state`` / ``link_state`` pytree shapes and branch tables, so they are
necessarily separate compiles — and collapses EVERY other swept axis inside
one compiled program per family cell
(``repro.experiments.sweep.make_batched_run_rounds``): the *algorithm* axis
(a traced per-trajectory ``algo_id`` into an ``AlgorithmSpec`` table) and the
hyperparameter axes (``lrs x gammas x alphas x sigma0s x deltas``) are
flattened with the seed axis into a single leading batch dimension.

Algorithms batch together when they are *state-compatible* —
``repro.core.algo_family`` groups them by the set of unified-state fields
they materialize, e.g. fedavg / fedavg_all / fedavg_known_p / fedpbc all
carry an empty state and run as ONE program; a mixed grid (say fedpbc +
fedau) falls back to one program per family. The runner cache is keyed by
the family (state structure), never by an individual algorithm name, so
sweeping any subset of a family reuses one compile.

Nothing swept is a compile-time constant: the algorithm is a traced index,
lr and gamma/period are traced scalars consumed by factories inside the
trace, sigma0/delta (and alpha's effect on connectivity) only shape the
traced per-trajectory ``p_base`` input, alpha's Dirichlet re-partition
travels as the traced ``ds_state`` index table, and the dataset arrays
themselves are traced ``shared`` inputs. Compiled runners are memoized in a
module-level cache whose key is therefore *structure-only* — e.g. the fig-8
alpha/gamma/delta/sigma0 ablations, an LR search, and a FedPBC-vs-baselines
comparison all reuse ONE compile per (family, scheme)
(``tests/test_traced_axes.py`` / ``tests/test_algo_axis.py`` count the
compiles).
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig
from repro.core.algorithms import (
    ALGORITHMS,
    algo_family,
    make_algorithm,
    make_algorithm_spec,
)
from repro.core.connectivity import build_base_probs, make_link_process
from repro.kernels.dispatch import FUSED_OPS, resolve_use_kernel
from repro.experiments.results import ResultsStore, buffered_summary, summarize
from repro.scale.buffer import (
    SYNC,
    Strategy,
    strategy_knob_columns,
)
from repro.scale.buffer import BUFFER_METRIC_KEYS as _BUFFER_KEYS
from repro.experiments.shard import (
    AUTO,
    pad_batch,
    resolve_batch_mesh,
    shard_batch,
)
from repro.sharding.specs import replicated_sharding
from repro.experiments.sweep import (
    CellBatch,
    eval_rounds,
    make_batched_run_rounds,
    stack_seed_keys,
)
from repro.experiments.tasks import (
    ClassificationTask,
    TracedClassificationTask,
    make_classification_task,
    make_traced_classification_task,
    make_traced_lm_task,
)
from repro.optim import paper_decay, sgd

# The paper's evaluation grid (§7.2): 7 algorithms x 6 link schemes.
ALGOS = ("fedpbc", "fedavg", "fedavg_all", "fedau", "f3ast",
         "fedavg_known_p", "mifa")

SCHEMES = {
    "bernoulli_ti": dict(scheme="bernoulli", time_varying=False),
    "bernoulli_tv": dict(scheme="bernoulli", time_varying=True),
    "markov_hom": dict(scheme="markov", time_varying=False),
    "markov_nonhom": dict(scheme="markov", time_varying=True),
    "cyclic": dict(scheme="cyclic", cyclic_reset=False),
    "cyclic_reset": dict(scheme="cyclic", cyclic_reset=True),
}

# The swept-inside-one-compile knobs, in flattening order: a hyperparameter
# point is one (lr, gamma, alpha, sigma0, delta) combination.
HPARAM_FIELDS = ("lr", "gamma", "alpha", "sigma0", "delta")


@dataclass(frozen=True)
class SweepSpec:
    """One declarative grid: which cells to run and with what protocol.

    The scalar fields (``lr``, ``gamma``, ``alpha``, ``sigma0``, ``delta``)
    give the default hyperparameter point; the plural axes (``lrs``,
    ``gammas``, ``alphas``, ``sigma0s``, ``deltas``) override them with a
    swept list whose cartesian product is flattened — together with ``seeds``
    (and, within a state-compatible family, ``algorithms``) — into the one
    batch axis of the compiled cell program. An empty hyperparameter axis
    means "use the scalar field".

    Specs are validated at construction: empty ``algorithms``/``schemes``/
    ``seeds`` axes, duplicate entries on any of them, and unknown
    algorithm/scheme names all raise an immediate ``ValueError`` naming the
    offending field, instead of failing deep inside tracing (or silently
    double-counting a row in every mean/CI).
    """

    algorithms: Tuple[str, ...] = ("fedpbc", "fedavg")
    schemes: Tuple[str, ...] = ("bernoulli_ti",)
    seeds: Tuple[int, ...] = (0,)
    rounds: int = 100
    eval_every: int = 25            # <= 0: single eval at the final round
    # federation protocol
    num_clients: int = 100
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.1                 # paper_decay base LR
    # Eq.-9 / heterogeneity knobs
    alpha: float = 0.1
    sigma0: float = 10.0
    delta: float = 0.02
    gamma: float = 0.5
    # hyperparameter axes (traced; empty tuple -> the scalar field above)
    lrs: Tuple[float, ...] = ()
    gammas: Tuple[float, ...] = ()
    alphas: Tuple[float, ...] = ()
    sigma0s: Tuple[float, ...] = ()
    deltas: Tuple[float, ...] = ()
    # shared-dataset / model knobs
    data_seed: int = 0
    dim: int = 32
    classes: int = 10
    hidden: int = 64
    n_per_class: int = 600
    n_train: int = 5000
    per_client: int = 64
    # server-aggregation path: True routes fusable families through the
    # backend-dispatched fused Pallas kernel (repro.kernels.dispatch), False
    # keeps the XLA masked-mean switch, None defers to the REPRO_USE_KERNEL
    # env default. Part of the runner-cache key (the two paths are distinct
    # traced programs); results match within the documented per-backend
    # tolerance (bitwise on CPU fp32 — tests/test_kernel_sweep.py).
    use_kernel: Optional[bool] = None
    # cross-device scale axes (repro.scale): the buffered semi-async
    # strategy axis — one more traced batched dimension of the compiled
    # cell program, (SYNC,) is the historical synchronous engine — and the
    # per-round cohort size C (None: all m clients materialize densely)
    strategies: Tuple[Strategy, ...] = (SYNC,)
    cohort_size: Optional[int] = None
    # extra FederationConfig field overrides, applied last (e.g.
    # (("fedau_K", 100), ("period", 20)))
    fed_overrides: Tuple[Tuple[str, Any], ...] = ()
    # workload: "classification" (the paper's Gaussian/MLP stand-in) or "lm"
    # (reduced-config transformer next-token task, repro.experiments.tasks
    # .make_traced_lm_task). For "lm" the lm_* knobs shape the model/corpus
    # (classes doubles as the number of corpus styles, per_client /
    # local_steps / batch_size keep their meaning), and dim/hidden/
    # n_per_class/n_train are ignored.
    task: str = "classification"
    lm_arch: str = "smollm-135m"
    lm_d_model: int = 64
    lm_layers: int = 2
    lm_seq: int = 32                # training context length
    lm_n_seqs: int = 256            # corpus size (train sequences)
    lm_n_test: int = 64             # held-out eval sequences

    def __post_init__(self):
        if self.task not in ("classification", "lm"):
            raise ValueError(
                f"SweepSpec.task={self.task!r}; expected 'classification' "
                f"or 'lm'")
        for axis in ("algorithms", "schemes", "seeds"):
            vals = getattr(self, axis)
            if not vals:
                raise ValueError(f"SweepSpec.{axis} is empty; give at least "
                                 f"one entry")
            if len(set(vals)) != len(vals):
                dupes = sorted({v for v in vals if vals.count(v) > 1})
                raise ValueError(
                    f"SweepSpec.{axis} contains duplicates {dupes}: each "
                    f"entry is one independent grid coordinate (duplicates "
                    f"would silently double-count rows and every mean/CI)")
        unknown = [a for a in self.algorithms if a not in ALGORITHMS]
        if unknown:
            raise ValueError(
                f"SweepSpec.algorithms contains unknown algorithms "
                f"{unknown}; available: {sorted(ALGORITHMS)}")
        unknown = [s for s in self.schemes if s not in SCHEMES]
        if unknown:
            raise ValueError(
                f"SweepSpec.schemes contains unknown schemes {unknown}; "
                f"available: {sorted(SCHEMES)}")
        if not self.strategies:
            raise ValueError(
                "SweepSpec.strategies is empty; give at least one Strategy "
                "(repro.scale.SYNC is the synchronous default)")
        bad = [s for s in self.strategies if not isinstance(s, Strategy)]
        if bad:
            raise ValueError(
                f"SweepSpec.strategies entries must be repro.scale.Strategy, "
                f"got {[type(s).__name__ for s in bad]}")
        names = [s.name for s in self.strategies]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(
                f"SweepSpec.strategies contains duplicate names {dupes}: "
                f"each strategy is one independent grid coordinate")
        if self.cohort_size is not None \
                and not 1 <= self.cohort_size <= self.num_clients:
            raise ValueError(
                f"SweepSpec.cohort_size={self.cohort_size} must be in "
                f"[1, num_clients={self.num_clients}]")
        pop = self.cohort_size if self.cohort_size is not None \
            else self.num_clients
        for s in self.strategies:
            if not 1 <= s.buffer_size <= pop:
                raise ValueError(
                    f"SweepSpec.strategies[{s.name!r}].buffer_size="
                    f"{s.buffer_size} must be in [1, {pop}] (at most the "
                    f"{'cohort size' if self.cohort_size else 'client count'}"
                    f" — a larger buffer could never fill)")
            if s.deadline_rounds < 1:
                raise ValueError(
                    f"SweepSpec.strategies[{s.name!r}].deadline_rounds="
                    f"{s.deadline_rounds} must be >= 1 (the buffer commits "
                    f"at a round boundary at the earliest)")
            if not 0.0 <= s.staleness_discount < 1.0:
                raise ValueError(
                    f"SweepSpec.strategies[{s.name!r}].staleness_discount="
                    f"{s.staleness_discount} must be in [0, 1)")
        if self.strategies != (SYNC,):
            stateful = [a for a in self.algorithms if a not in FUSED_OPS]
            if stateful:
                raise ValueError(
                    f"SweepSpec.strategies has buffered entries but "
                    f"algorithms {stateful} keep per-client state; buffered "
                    f"semi-async aggregation covers the empty-state family "
                    f"{sorted(FUSED_OPS)} only")

    def hparam_points(self) -> List[Dict[str, float]]:
        """The flattened hyperparameter grid: one dict per point, in
        ``itertools.product`` order over ``HPARAM_FIELDS``."""
        axes = [tuple(getattr(self, f + "s")) or (getattr(self, f),)
                for f in HPARAM_FIELDS]
        return [dict(zip(HPARAM_FIELDS, combo))
                for combo in itertools.product(*axes)]

    def cell_config(self, algo: str, scheme: str) -> FederationConfig:
        if scheme not in SCHEMES:
            raise KeyError(f"unknown scheme {scheme!r}; available: "
                           f"{sorted(SCHEMES)}")
        if algo not in ALGORITHMS:
            raise KeyError(f"unknown algorithm {algo!r}; available: "
                           f"{sorted(ALGORITHMS)}")
        overrides = dict(self.fed_overrides)
        # lr/alpha/sigma0/delta/gamma are hyperparameter-point knobs the
        # executor feeds the program as traced inputs — an override here would
        # reach FederationConfig but never the simulation, a silent no-op.
        # Force them through the spec fields / axes instead.
        data_knobs = {"alpha", "sigma0", "delta", "gamma"} & set(overrides)
        if data_knobs:
            raise ValueError(
                f"set {sorted(data_knobs)} via SweepSpec fields or axes, not "
                f"fed_overrides (they are traced hyperparameter inputs)")
        kw: Dict[str, Any] = dict(
            algorithm=algo, num_clients=self.num_clients,
            local_steps=self.local_steps, gamma=self.gamma, delta=self.delta,
            sigma0=self.sigma0, alpha=self.alpha, **SCHEMES[scheme])
        kw.update(overrides)
        return FederationConfig(**kw)


@dataclass
class CellResult:
    """One grid cell's S-seed outcome at one hyperparameter point
    (host-side numpy)."""

    algo: str
    scheme: str
    seeds: Tuple[int, ...]
    rounds: int
    eval_rounds: List[int]          # [E] round index of each eval
    test_acc: np.ndarray            # [S, E]
    train_acc: np.ndarray           # [S] final train accuracy
    loss: np.ndarray                # [S, K] per-round mean train loss
    num_active: np.ndarray          # [S, K] active-client counts
    # the point's coordinates on the swept axes (lr/gamma/alpha/sigma0/delta)
    hparams: Dict[str, float] = field(default_factory=dict)
    # the row's strategy-axis coordinate ("sync" = the synchronous engine)
    strategy: str = "sync"
    # population the participation summary normalizes by (0: unknown/legacy)
    num_clients: int = 0
    # buffered-mode per-round traces (None for synchronous cells)
    commit: Optional[np.ndarray] = None             # [S, K] commit indicator
    commit_staleness: Optional[np.ndarray] = None   # [S, K] mean buffer age

    def final_test(self, window: int = 3) -> np.ndarray:
        """Per-seed mean test accuracy over the last ``window`` evals (the
        historical table-1 reduction)."""
        w = min(window, self.test_acc.shape[1])
        return self.test_acc[:, -w:].mean(axis=1)

    def summary(self) -> Dict[str, Dict[str, float]]:
        out = {"test_acc": summarize(self.final_test()),
               "train_acc": summarize(self.train_acc)}
        if self.num_clients and self.num_active.size:
            # mean per-round participation rate (of the materialized
            # population: m dense, C in cohort mode)
            out["participation"] = summarize(
                self.num_active.mean(axis=1) / self.num_clients)
        if self.commit is not None and self.commit.size:
            out.update(buffered_summary(self.commit, self.commit_staleness))
        return out


# --------------------------------------------------------------------------
# Executor with cross-cell compile/task/partition caches
# --------------------------------------------------------------------------

_TASK_CACHE: Dict[tuple, ClassificationTask] = {}
_TRACED_TASK_CACHE: Dict[tuple, TracedClassificationTask] = {}
_PARTITION_CACHE: Dict[tuple, np.ndarray] = {}
_RUNNER_CACHE: Dict[tuple, Any] = {}


def _task_key(spec: SweepSpec) -> tuple:
    """Structural dataset/model identity — deliberately alpha-free (the
    partition is a per-point traced input, not part of the task)."""
    return (spec.data_seed, spec.num_clients, spec.dim, spec.classes,
            spec.hidden, spec.n_per_class, spec.n_train,
            spec.per_client, spec.local_steps, spec.batch_size,
            spec.task, spec.lm_arch, spec.lm_d_model, spec.lm_layers,
            spec.lm_seq, spec.lm_n_seqs, spec.lm_n_test)


def get_task(spec: SweepSpec) -> ClassificationTask:
    """The constant-capturing task at the spec's scalar alpha (kept for the
    sequential baselines; the executor itself runs on ``get_traced_task``)."""
    if spec.task != "classification":
        raise ValueError(
            f"get_task covers the constant classification baseline only; "
            f"the {spec.task!r} workload is traced-only (get_traced_task)")
    key = _task_key(spec) + (spec.alpha,)
    if key not in _TASK_CACHE:
        _TASK_CACHE[key] = make_classification_task(
            data_seed=spec.data_seed, num_clients=spec.num_clients,
            dim=spec.dim, classes=spec.classes, hidden=spec.hidden,
            n_per_class=spec.n_per_class, n_train=spec.n_train,
            alpha=spec.alpha, per_client=spec.per_client,
            local_steps=spec.local_steps, batch_size=spec.batch_size)
    return _TASK_CACHE[key]


def get_traced_task(spec: SweepSpec) -> TracedClassificationTask:
    key = _task_key(spec)
    if key not in _TRACED_TASK_CACHE:
        if spec.task == "lm":
            _TRACED_TASK_CACHE[key] = make_traced_lm_task(
                data_seed=spec.data_seed, num_clients=spec.num_clients,
                arch=spec.lm_arch, d_model=spec.lm_d_model,
                layers=spec.lm_layers, seq_len=spec.lm_seq,
                classes=spec.classes, n_seqs=spec.lm_n_seqs,
                n_test=spec.lm_n_test, per_client=spec.per_client,
                local_steps=spec.local_steps, batch_size=spec.batch_size)
        else:
            _TRACED_TASK_CACHE[key] = make_traced_classification_task(
                data_seed=spec.data_seed, num_clients=spec.num_clients,
                dim=spec.dim, classes=spec.classes, hidden=spec.hidden,
                n_per_class=spec.n_per_class, n_train=spec.n_train,
                per_client=spec.per_client, local_steps=spec.local_steps,
                batch_size=spec.batch_size)
    return _TRACED_TASK_CACHE[key]


def get_partition(spec: SweepSpec, alpha: float) -> np.ndarray:
    """Cached Dirichlet(alpha) index table for the spec's dataset."""
    key = _task_key(spec) + (alpha,)
    if key not in _PARTITION_CACHE:
        _PARTITION_CACHE[key] = get_traced_task(spec).partition(alpha)
    return _PARTITION_CACHE[key]


def _has_strategy_axis(spec: SweepSpec) -> bool:
    """Whether the spec runs the buffered engine: any strategy besides the
    bare synchronous default. (SYNC,) keeps the historical program — note a
    single non-sync strategy, or even (SYNC, buffered), flips the WHOLE
    cell onto the buffered trace; the degenerate SYNC knobs there reproduce
    the synchronous results bit-for-bit (tests/test_staleness.py)."""
    return spec.strategies != (SYNC,)


def _runner_for(spec: SweepSpec, fed: FederationConfig, task,
                metric_keys, shard_mesh=None) -> Any:
    # Everything swept reaches the compiled program through traced inputs —
    # zero the hyperparameter knobs so cells differing only in them share one
    # compiled runner, and canonicalize the algorithm name to its
    # state-compatible family so the cache is keyed by state STRUCTURE, not
    # by which member happens to run: every runner is built over the FULL
    # family table (the traced algo_id selects the member), so fedpbc and
    # fedavg cells hand back the same object. The runner's closures keep a
    # reference to `fed`, but consume only its structural fields (scheme,
    # local_steps, num_clients, per-family static knobs like fedau_K):
    # gamma/period go through traced hparams, and alpha/sigma0/delta never
    # leave the host (they shape p_base / the partition, both batch inputs).
    family = algo_family(fed.algorithm)
    canon = dataclasses.replace(fed, alpha=0.0, sigma0=0.0, delta=0.0,
                                gamma=0.0, period=0, algorithm=family[0])
    # use_kernel picks between two distinct traced programs (fused kernel vs
    # XLA switch), so the resolved bool is part of the cache key; within one
    # sweep the value is constant, so a whole grid still compiles each
    # (family, scheme) stage pair exactly once.
    use_kernel = resolve_use_kernel(spec.use_kernel)
    # the scale modes are distinct traced programs: cohort size changes
    # every client-axis shape, buffered threads a BufferState + knob inputs
    buffered = _has_strategy_axis(spec)
    # a 2-D shard_mesh bakes placement constraints into the trace, so it is
    # a distinct program; jax Meshes hash by (devices, axes), so equal
    # meshes share the cache entry
    key = (_task_key(spec), canon, spec.rounds, spec.eval_every,
           tuple(metric_keys), use_kernel, spec.cohort_size, buffered,
           shard_mesh)
    if key not in _RUNNER_CACHE:
        algo = make_algorithm_spec(family, fed)
        _RUNNER_CACHE[key] = make_batched_run_rounds(
            task.loss_fn, algo, fed,
            optimizer_factory=lambda hp: sgd(paper_decay(hp["lr"])),
            link_factory=lambda p, hp: make_link_process(
                p, fed, gamma=hp["gamma"], period=hp["period"]),
            source_factory=task.source_factory,
            init_params=task.init_params,
            num_rounds=spec.rounds,
            eval_every=spec.eval_every,
            eval_fn=task.eval_test,
            metric_keys=metric_keys,
            use_kernel=use_kernel,
            cohort_size=spec.cohort_size,
            buffered=buffered,
            shard_mesh=shard_mesh)
    return _RUNNER_CACHE[key]


def segment_runner_for(spec: SweepSpec, algo: str, scheme: str, *,
                       segment_rounds: int,
                       metric_keys=("loss", "num_active")) -> Any:
    """The adaptive-search controller's entry point into the runner cache
    (``repro.experiments.search``): a resumable ``carry_out`` runner that
    scans exactly ``segment_rounds`` rounds per dispatch, with
    ``eval_every == segment_rounds`` so each segment fires exactly one
    in-scan eval at its last round (the controller's prune signal).

    Cache discipline matches ``_runner_for``: the key is *structure-only*
    (task shape, zeroed-canonical fed config, segment length, metric keys,
    kernel/scale modes), so every candidate the controller ever packs —
    unseen lr/gamma values, re-batched survivor subsets, refilled fresh
    points — rides ONE compiled (init, scan) pair per (family, scheme);
    only the segment length itself is a new program. Shares
    ``_RUNNER_CACHE`` with the one-shot runners under a ``"segment"`` tag,
    and all task/partition/batch caches downstream."""
    task = get_traced_task(spec)
    fed = spec.cell_config(algo, scheme)
    family = algo_family(fed.algorithm)
    canon = dataclasses.replace(fed, alpha=0.0, sigma0=0.0, delta=0.0,
                                gamma=0.0, period=0, algorithm=family[0])
    use_kernel = resolve_use_kernel(spec.use_kernel)
    buffered = _has_strategy_axis(spec)
    key = ("segment", _task_key(spec), canon, segment_rounds,
           tuple(metric_keys), use_kernel, spec.cohort_size, buffered)
    if key not in _RUNNER_CACHE:
        algo_spec = make_algorithm_spec(family, fed)
        _RUNNER_CACHE[key] = make_batched_run_rounds(
            task.loss_fn, algo_spec, fed,
            optimizer_factory=lambda hp: sgd(paper_decay(hp["lr"])),
            link_factory=lambda p, hp: make_link_process(
                p, fed, gamma=hp["gamma"], period=hp["period"]),
            source_factory=task.source_factory,
            init_params=task.init_params,
            num_rounds=segment_rounds,
            eval_every=segment_rounds,
            eval_fn=task.eval_test,
            metric_keys=metric_keys,
            use_kernel=use_kernel,
            cohort_size=spec.cohort_size,
            buffered=buffered,
            carry_out=True)
    return _RUNNER_CACHE[key]


def point_base_probs(spec: SweepSpec, point: Dict[str, float]) -> jnp.ndarray:
    """Per-seed Eq.-9 connection-probability draws for one hyperparameter
    point, stacked to [S, m]. The per-seed key protocol (PRNGKey(seed)) is the
    historical one, so the default point reproduces ``seed_base_probs``."""
    return jnp.stack([
        build_base_probs(jax.random.PRNGKey(s), spec.num_clients,
                         spec.classes, alpha=point["alpha"],
                         sigma0=point["sigma0"], delta=point["delta"])[0]
        for s in spec.seeds])


def seed_base_probs(spec: SweepSpec) -> jnp.ndarray:
    """[S, m] draws at the spec's scalar (default) hyperparameter point."""
    return point_base_probs(
        spec, dict(alpha=spec.alpha, sigma0=spec.sigma0, delta=spec.delta))


_BATCH_CACHE: Dict[tuple, tuple] = {}


def _batch_key(spec: SweepSpec) -> tuple:
    """Identity of a spec's fed-independent batch contents (dataset/model
    shape, seed set, hyperparameter points). ONE definition shared by the
    host-side ``_BATCH_CACHE`` and the device-side ``_SHARDED_BATCH_CACHE``
    so the two can never desync on a future spec field."""
    return (_task_key(spec), spec.seeds, spec.strategies, spec.cohort_size,
            tuple(tuple(sorted(pt.items())) for pt in spec.hparam_points()))


def _batch_parts(spec: SweepSpec) -> tuple:
    """The fed-independent pieces of a cell batch (keys, p_base, lr/gamma
    arrays, partition stack), memoized per (dataset, seeds, points): a full
    grid calls ``make_cell_batch`` once per (algorithm, scheme) cell, and
    only the ``period`` array can differ between those calls."""
    points = spec.hparam_points()
    key = _batch_key(spec)
    if key not in _BATCH_CACHE:
        S = len(spec.seeds)
        seed_bundle = stack_seed_keys(spec.seeds)
        keys = jax.tree.map(lambda k: jnp.concatenate([k] * len(points)),
                            seed_bundle)
        # the Eq.-9 draw depends only on (alpha, sigma0, delta): memoize so
        # an lr/gamma-only ablation doesn't redo the sampling per point
        probs_memo: Dict[tuple, jnp.ndarray] = {}

        def probs(pt):
            k = (pt["alpha"], pt["sigma0"], pt["delta"])
            if k not in probs_memo:
                probs_memo[k] = point_base_probs(spec, pt)
            return probs_memo[k]

        p_base = jnp.concatenate([probs(pt) for pt in points])
        lr = jnp.asarray([pt["lr"] for pt in points for _ in range(S)],
                         jnp.float32)
        gamma = jnp.asarray([pt["gamma"] for pt in points for _ in range(S)],
                            jnp.float32)
        idx = jnp.asarray(np.stack([get_partition(spec, pt["alpha"])
                                    for pt in points for _ in range(S)]))
        _BATCH_CACHE[key] = (keys, p_base, lr, gamma, idx)
    return _BATCH_CACHE[key]


# {(batch_key, mesh): {"shared": replicated dataset, "groups": {algos:
# (sharded_batch, b_real)}}} — one base entry (the most recent (spec, mesh))
# whose ONE committed dataset copy is reused by every algorithm-group
# sub-entry, so a mixed-family sweep alternating groups per scheme neither
# thrashes the committed arrays nor pins one replicated dataset per family
_SHARDED_BATCH_CACHE: Dict[tuple, Dict[str, Any]] = {}


def _sharded_cell_batch(spec: SweepSpec, fed: FederationConfig,
                        task: TracedClassificationTask, mesh,
                        algos: Tuple[str, ...]) -> tuple:
    """``make_cell_batch`` padded to the mesh's device count and committed to
    it, memoized like ``_batch_parts``: one device transfer of the heavy
    fields (key/p_base/partition arrays, the replicated dataset — on real
    multi-host backends, real H2D traffic) per (dataset, seeds, points,
    algos, mesh). ``fed`` is deliberately NOT in the cache key: only the tiny
    ``[B_padded]`` ``period`` hparam vector depends on it, so it is rebuilt
    and committed per call — cells (or whole sweeps) differing only in a
    ``period`` override reuse the cached heavy arrays instead of pinning a
    duplicate copy per value. Returns ``(sharded_batch, B_real)``; equal
    meshes hash equal, so a fresh auto-resolved mesh over the same devices
    still hits.

    Unlike the host-side caches, this one holds DEVICE memory (a replicated
    dataset copy per device), so it keeps only the most recent (spec, mesh)
    base entry — with one sub-entry per algorithm group, since a
    mixed-family sweep alternates groups within one sweep (evicting per
    group would re-commit the heavy arrays once per (scheme, family)). The
    replicated dataset is committed ONCE at the base and shared by every
    group sub-entry (``shard_batch``'s device_put is a no-op on an array
    already carrying the target sharding), so a many-family sweep pins one
    dataset copy per device, not one per family. A long-lived process
    hopping specs/meshes still never accumulates committed duplicates
    beyond one sweep's groups."""
    base = _batch_key(spec) + (mesh,)
    entry = _SHARDED_BATCH_CACHE.get(base)
    if entry is None:
        _SHARDED_BATCH_CACHE.clear()
        entry = _SHARDED_BATCH_CACHE.setdefault(
            base, {"shared": None, "groups": {}})
    if algos not in entry["groups"]:
        batch = make_cell_batch(spec, fed, task, algos=algos)
        if entry["shared"] is None:
            entry["shared"] = jax.tree.map(
                lambda x: jax.device_put(x, replicated_sharding(mesh)),
                batch.shared)
        batch = dataclasses.replace(batch, shared=entry["shared"])
        padded, b_real = pad_batch(batch, mesh.shape["batch"])
        entry["groups"][algos] = (shard_batch(padded, mesh), b_real)
    sharded, b_real = entry["groups"][algos]
    lr = sharded.hparams["lr"]
    period = jax.device_put(
        jnp.full(lr.shape, float(fed.period), jnp.float32), lr.sharding)
    return CellBatch(keys=sharded.keys, p_base=sharded.p_base,
                     hparams=dict(sharded.hparams, period=period),
                     data=sharded.data, shared=sharded.shared,
                     algo_id=sharded.algo_id), b_real


def make_cell_batch(spec: SweepSpec, fed: FederationConfig,
                    task: TracedClassificationTask,
                    algos: Optional[Tuple[str, ...]] = None) -> CellBatch:
    """Flatten (algorithm x strategy x hyperparameter point x seed) into one
    [B]-leading batch, algo-major, then strategy-major, then point-major:
    ``b = ((algo_index * n_strategies + strategy_index) * n_points
    + point_index) * len(seeds) + seed_index`` (without a strategy axis,
    n_strategies == 1 and the historical layout is unchanged).

    ``algos`` (default: just ``fed.algorithm``) must all belong to one
    state-compatible family; the batch's ``algo_id`` column carries each
    trajectory's index into that family's canonical ``AlgorithmSpec`` table,
    so the same compiled family runner serves any subset. With a strategy
    axis (``_has_strategy_axis``), the per-trajectory buffer knobs travel
    as four more traced hparam columns."""
    if algos is None:
        algos = (fed.algorithm,)
    family = algo_family(algos[0])
    bad = [a for a in algos if a not in family]
    if bad:
        raise ValueError(
            f"algorithms {bad} are not state-compatible with {algos[0]!r} "
            f"(family {family}); run them as separate cells")
    ids = [family.index(a) for a in algos]
    keys, p_base, lr, gamma, idx = _batch_parts(spec)
    knobs: Dict[str, jnp.ndarray] = {}
    if _has_strategy_axis(spec):
        n_str = len(spec.strategies)
        rep_s = lambda x: jnp.concatenate([x] * n_str)
        keys = jax.tree.map(rep_s, keys)
        p_base, lr, gamma, idx = (rep_s(p_base), rep_s(lr), rep_s(gamma),
                                  rep_s(idx))
        knobs = strategy_knob_columns(spec.strategies,
                                      lr.shape[0] // n_str)
    if len(algos) > 1:
        rep = lambda x: jnp.concatenate([x] * len(algos))
        keys = jax.tree.map(rep, keys)
        p_base, lr, gamma, idx = rep(p_base), rep(lr), rep(gamma), rep(idx)
        knobs = {k: rep(v) for k, v in knobs.items()}
    hparams = {
        "lr": lr,
        "gamma": gamma,
        "period": jnp.full((lr.shape[0],), float(fed.period), jnp.float32),
        **knobs,
    }
    block = lr.shape[0] // len(algos)
    algo_id = jnp.asarray(np.repeat(ids, block), jnp.int32)
    return CellBatch(keys=keys, p_base=p_base, hparams=hparams,
                     data={"idx": idx}, shared=task.shared, algo_id=algo_id)


def _run_batch(spec: SweepSpec, algos: Tuple[str, ...], scheme: str, *,
               metric_keys=("loss", "num_active"),
               mesh=AUTO, devices=None) -> List[CellResult]:
    """Run one (state-compatible algorithm group, scheme) cell: ALL algos x
    hyperparameter points x seeds in one batched program; returns
    ``CellResult`` rows algo-major, point-major."""
    task = get_traced_task(spec)
    fed = spec.cell_config(algos[0], scheme)
    buffered = _has_strategy_axis(spec)
    if buffered:
        metric_keys = tuple(metric_keys) + tuple(
            k for k in _BUFFER_KEYS if k not in metric_keys)
    batch_mesh = resolve_batch_mesh(mesh, devices)
    # a mesh with a "model" axis selects the 2-D path: the runner itself is
    # built for the mesh (in-trace placement constraints + spmd axis names)
    mesh2d = batch_mesh if (batch_mesh is not None
                            and "model" in batch_mesh.axis_names) else None
    runner = _runner_for(spec, fed, task, metric_keys, shard_mesh=mesh2d)
    if batch_mesh is not None:
        # memoized pad + device_put (shard.run_sharded is the uncached
        # one-shot equivalent); padding rows are sliced off right here, so
        # nothing downstream ever sees them
        sharded, b_real = _sharded_cell_batch(spec, fed, task, batch_mesh,
                                              algos)
        states, out = runner(sharded)
        if sharded.batch_size != b_real:
            states, out = jax.tree.map(lambda x: x[:b_real], (states, out))
    else:
        states, out = runner(make_cell_batch(spec, fed, task, algos=algos))

    points = spec.hparam_points()
    S = len(spec.seeds)
    if "evals" in out:
        test_acc = np.asarray(out["evals"])
        rounds_at = eval_rounds(spec.rounds, spec.eval_every)
    else:
        test_acc = np.asarray(jax.vmap(task.eval_test, in_axes=(0, None))(
            states.server, task.shared))[:, None]
        rounds_at = [spec.rounds]
    train_acc = np.asarray(jax.vmap(task.eval_train, in_axes=(0, None))(
        states.server, task.shared))
    mets = {k: np.asarray(v) for k, v in out["metrics"].items()}
    strategies = spec.strategies
    n_str = len(strategies)
    B = len(algos) * n_str * len(points) * S
    # the per-round population the participation summary normalizes by
    pop = spec.cohort_size if spec.cohort_size is not None \
        else spec.num_clients

    def rows(a, ai, si, pi):
        lo = ((ai * n_str + si) * len(points) + pi) * S
        return a[lo:lo + S]

    return [
        CellResult(
            algo=algo, scheme=scheme, seeds=tuple(spec.seeds),
            rounds=spec.rounds, eval_rounds=rounds_at,
            test_acc=rows(test_acc, ai, si, pi),
            train_acc=rows(train_acc, ai, si, pi),
            loss=rows(mets.get("loss", np.zeros((B, 0))), ai, si, pi),
            num_active=rows(mets.get("num_active", np.zeros((B, 0))),
                            ai, si, pi),
            hparams=dict(pt),
            strategy=strat.name,
            # plain dense synchronous cells keep the historical two-key
            # summary; participation only appears where it is informative
            # (cohort mode normalizes by C, buffered rows by the buffer pool)
            num_clients=(pop if (strat.name != "sync"
                                 or spec.cohort_size is not None) else 0),
            commit=(rows(mets["commit"], ai, si, pi) if buffered else None),
            commit_staleness=(rows(mets["commit_staleness"], ai, si, pi)
                              if buffered else None))
        for ai, algo in enumerate(algos)
        for si, strat in enumerate(strategies)
        for pi, pt in enumerate(points)]


def run_cell_batch(spec: SweepSpec, algo: str, scheme: str, *,
                   metric_keys=("loss", "num_active"),
                   mesh=AUTO, devices=None) -> List[CellResult]:
    """Run one (algo, scheme) cell: ALL hyperparameter points x seeds in one
    batched program; returns one ``CellResult`` per point. (The program is
    the algorithm's shared FAMILY runner with a constant ``algo_id`` column —
    ``run_sweep`` additionally joins whole state-compatible groups into one
    dispatch.)

    ``mesh``/``devices`` pick the execution placement (see
    ``repro.experiments.shard.resolve_batch_mesh``): by default the batch
    axis is sharded over a ``("batch",)`` mesh of all visible devices when
    more than one is up (B padded to a device multiple, padding dropped on
    the host), and runs on one device otherwise; ``mesh=None`` forces the
    single-device path, an explicit ``devices`` list or ``Mesh`` pins the
    placement. Per-trajectory results are identical either way, and both
    paths share the same cached runner (the compiled executables differ, the
    traced program does not).
    """
    return _run_batch(spec, (algo,), scheme, metric_keys=metric_keys,
                      mesh=mesh, devices=devices)


def run_cell(spec: SweepSpec, algo: str, scheme: str, *,
             metric_keys=("loss", "num_active"),
             mesh=AUTO, devices=None) -> CellResult:
    """Single-point convenience wrapper around ``run_cell_batch``."""
    n_points = len(spec.hparam_points()) * len(spec.strategies)
    if n_points != 1:       # before compiling/running anything
        raise ValueError(
            f"spec has {n_points} hyperparameter points x strategy rows; "
            f"use run_cell_batch for swept axes")
    return run_cell_batch(spec, algo, scheme, metric_keys=metric_keys,
                          mesh=mesh, devices=devices)[0]


def run_sweep(spec: SweepSpec, *, store: Optional[ResultsStore] = None,
              suite: str = "sweep",
              metric_keys=("loss", "num_active"),
              mesh=AUTO, devices=None) -> List[CellResult]:
    """Execute the full grid; optionally append every (cell, hyperparameter
    point) row to ``store`` with its coordinates recorded (the ``algo``
    field is each row's algorithm-axis coordinate).

    Within each scheme, algorithms are grouped into state-compatible
    families (``repro.core.algo_family``) and every group runs as ONE
    batched program over the joint (algo x point x seed) axis; a mixed-state
    grid simply falls back to one program per family. Results (and store
    rows) keep the historical ``scheme -> algorithm -> point`` order
    regardless of how the groups executed."""
    # validate every cell upfront — a typo in the last algorithm must not
    # surface as a KeyError after earlier cells ran for minutes
    for scheme in spec.schemes:
        for algo in spec.algorithms:
            spec.cell_config(algo, scheme)
    cells = []
    for scheme in spec.schemes:
        groups: Dict[Tuple[str, ...], List[str]] = {}
        for algo in dict.fromkeys(spec.algorithms):   # unique, in order
            groups.setdefault(algo_family(algo), []).append(algo)
        by_algo: Dict[str, List[CellResult]] = {}
        n_points = len(spec.hparam_points()) * len(spec.strategies)
        pending = list(spec.algorithms)     # emission order (per occurrence)

        def emit(algo):
            for cell in by_algo[algo]:
                cells.append(cell)
                if store is not None:
                    arrays = {"test_acc": cell.test_acc,
                              "train_acc": cell.train_acc,
                              "loss": cell.loss,
                              "num_active": cell.num_active}
                    if cell.commit is not None:
                        arrays["commit"] = cell.commit
                        arrays["commit_staleness"] = cell.commit_staleness
                    store.append(
                        {"suite": suite, "algo": algo, "scheme": scheme,
                         "strategy": cell.strategy,
                         "seeds": list(spec.seeds), "rounds": spec.rounds,
                         "eval_every": spec.eval_every,
                         "hparams": dict(cell.hparams),
                         "spec": dataclasses.asdict(spec),
                         "eval_rounds": cell.eval_rounds,
                         "summary": cell.summary()},
                        arrays=arrays)

        # groups run in first-appearance order; completed results are emitted
        # (and PERSISTED) as soon as spec order allows, so a crash in a later
        # family (e.g. mifa's [m, ...] memory OOMing) never discards rows an
        # earlier family already computed
        try:
            for group in groups.values():
                results = _run_batch(spec, tuple(group), scheme,
                                     metric_keys=metric_keys,
                                     mesh=mesh, devices=devices)
                for ai, algo in enumerate(group):
                    by_algo[algo] = results[ai * n_points:(ai + 1) * n_points]
                while pending and pending[0] in by_algo:
                    emit(pending.pop(0))
        finally:
            # no-op on success (pending drained); on a crash, salvage every
            # result a completed group already computed — including ones the
            # spec-order gate was still holding back behind the crashed
            # family (e.g. ("fedpbc", "fedau", "fedavg") with fedau failing:
            # fedavg ran with fedpbc and must persist too)
            for algo in pending:
                if algo in by_algo:
                    emit(algo)
    return cells
