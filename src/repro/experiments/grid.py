"""Declarative sweep grids: ``SweepSpec`` -> batched device simulations.

A paper table is a grid of ``(algorithm x unreliable-link scheme x seed)``
cells. The executor walks the *algorithm x scheme* axes in Python — distinct
algorithms / schemes carry distinct ``algo_state`` / ``link_state`` pytree
structures and aggregation code, so they are necessarily separate compiles —
and collapses the *seed* axis inside each cell with the vmapped runner
(``repro.experiments.sweep.make_vmap_run_rounds``): S seeds run as one
compiled program.

Compiled runners (and the shared device-resident task behind them) are
memoized in module-level caches keyed by everything that changes the compiled
program. Eq.-9 knobs (``sigma0``, ``delta``) only shape the traced per-seed
``p_base`` input, so e.g. the fig-8 delta/sigma0 ablations reuse ONE compile
across all swept values; ``alpha`` additionally re-partitions the dataset
(a jit constant) and so rebuilds the task.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig
from repro.core.algorithms import ALGORITHMS, make_algorithm
from repro.core.connectivity import build_base_probs, make_link_process
from repro.experiments.results import ResultsStore, summarize
from repro.experiments.sweep import (
    eval_rounds,
    make_vmap_run_rounds,
    stack_seed_keys,
)
from repro.experiments.tasks import ClassificationTask, make_classification_task
from repro.optim import paper_decay, sgd

# The paper's evaluation grid (§7.2): 7 algorithms x 6 link schemes.
ALGOS = ("fedpbc", "fedavg", "fedavg_all", "fedau", "f3ast",
         "fedavg_known_p", "mifa")

SCHEMES = {
    "bernoulli_ti": dict(scheme="bernoulli", time_varying=False),
    "bernoulli_tv": dict(scheme="bernoulli", time_varying=True),
    "markov_hom": dict(scheme="markov", time_varying=False),
    "markov_nonhom": dict(scheme="markov", time_varying=True),
    "cyclic": dict(scheme="cyclic", cyclic_reset=False),
    "cyclic_reset": dict(scheme="cyclic", cyclic_reset=True),
}


@dataclass(frozen=True)
class SweepSpec:
    """One declarative grid: which cells to run and with what protocol."""

    algorithms: Tuple[str, ...] = ("fedpbc", "fedavg")
    schemes: Tuple[str, ...] = ("bernoulli_ti",)
    seeds: Tuple[int, ...] = (0,)
    rounds: int = 100
    eval_every: int = 25            # <= 0: single eval at the final round
    # federation protocol
    num_clients: int = 100
    local_steps: int = 5
    batch_size: int = 32
    lr: float = 0.1                 # paper_decay base LR
    # Eq.-9 / heterogeneity knobs
    alpha: float = 0.1
    sigma0: float = 10.0
    delta: float = 0.02
    gamma: float = 0.5
    # shared-dataset / model knobs
    data_seed: int = 0
    dim: int = 32
    classes: int = 10
    hidden: int = 64
    n_per_class: int = 600
    n_train: int = 5000
    per_client: int = 64
    # extra FederationConfig field overrides, applied last (e.g.
    # (("fedau_K", 100), ("period", 20)))
    fed_overrides: Tuple[Tuple[str, Any], ...] = ()

    def cell_config(self, algo: str, scheme: str) -> FederationConfig:
        if scheme not in SCHEMES:
            raise KeyError(f"unknown scheme {scheme!r}; available: "
                           f"{sorted(SCHEMES)}")
        if algo not in ALGORITHMS:
            raise KeyError(f"unknown algorithm {algo!r}; available: "
                           f"{sorted(ALGORITHMS)}")
        overrides = dict(self.fed_overrides)
        # alpha/sigma0/delta shape the dataset partition and the Eq.-9 p_base
        # draw, which the executor builds from the SPEC fields — an override
        # here would reach FederationConfig but never the simulation, a
        # silent no-op. Force them through the spec fields instead.
        data_knobs = {"alpha", "sigma0", "delta"} & set(overrides)
        if data_knobs:
            raise ValueError(
                f"set {sorted(data_knobs)} via SweepSpec fields, not "
                f"fed_overrides (they only affect the task / p_base inputs)")
        kw: Dict[str, Any] = dict(
            algorithm=algo, num_clients=self.num_clients,
            local_steps=self.local_steps, gamma=self.gamma, delta=self.delta,
            sigma0=self.sigma0, alpha=self.alpha, **SCHEMES[scheme])
        kw.update(overrides)
        return FederationConfig(**kw)


@dataclass
class CellResult:
    """One grid cell's S-seed outcome (host-side numpy)."""

    algo: str
    scheme: str
    seeds: Tuple[int, ...]
    rounds: int
    eval_rounds: List[int]          # [E] round index of each eval
    test_acc: np.ndarray            # [S, E]
    train_acc: np.ndarray           # [S] final train accuracy
    loss: np.ndarray                # [S, K] per-round mean train loss
    num_active: np.ndarray          # [S, K] active-client counts

    def final_test(self, window: int = 3) -> np.ndarray:
        """Per-seed mean test accuracy over the last ``window`` evals (the
        historical table-1 reduction)."""
        w = min(window, self.test_acc.shape[1])
        return self.test_acc[:, -w:].mean(axis=1)

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {"test_acc": summarize(self.final_test()),
                "train_acc": summarize(self.train_acc)}


# --------------------------------------------------------------------------
# Executor with cross-cell compile/task caches
# --------------------------------------------------------------------------

_TASK_CACHE: Dict[tuple, ClassificationTask] = {}
_RUNNER_CACHE: Dict[tuple, Any] = {}


def _task_key(spec: SweepSpec) -> tuple:
    return (spec.data_seed, spec.num_clients, spec.dim, spec.classes,
            spec.hidden, spec.n_per_class, spec.n_train, spec.alpha,
            spec.per_client, spec.local_steps, spec.batch_size)


def get_task(spec: SweepSpec) -> ClassificationTask:
    key = _task_key(spec)
    if key not in _TASK_CACHE:
        _TASK_CACHE[key] = make_classification_task(
            data_seed=spec.data_seed, num_clients=spec.num_clients,
            dim=spec.dim, classes=spec.classes, hidden=spec.hidden,
            n_per_class=spec.n_per_class, n_train=spec.n_train,
            alpha=spec.alpha, per_client=spec.per_client,
            local_steps=spec.local_steps, batch_size=spec.batch_size)
    return _TASK_CACHE[key]


def _runner_for(spec: SweepSpec, fed: FederationConfig, task,
                metric_keys) -> Any:
    # sigma0/delta (and alpha, via the task key) reach the program only
    # through traced inputs — zero them so cells differing in just those
    # knobs share one compiled runner
    canon = dataclasses.replace(fed, alpha=0.0, sigma0=0.0, delta=0.0)
    key = (_task_key(spec), canon, spec.rounds, spec.eval_every, spec.lr,
           tuple(metric_keys))
    if key not in _RUNNER_CACHE:
        algo = make_algorithm(fed)
        _RUNNER_CACHE[key] = make_vmap_run_rounds(
            task.loss_fn, sgd(paper_decay(spec.lr)), algo, fed, task.source,
            link_factory=lambda p: make_link_process(p, fed),
            init_params=task.init_params,
            num_rounds=spec.rounds,
            eval_every=spec.eval_every,
            eval_fn=task.eval_test,
            metric_keys=metric_keys)
    return _RUNNER_CACHE[key]


def seed_base_probs(spec: SweepSpec) -> jnp.ndarray:
    """Per-seed Eq.-9 connection-probability draws, stacked to [S, m]."""
    return jnp.stack([
        build_base_probs(jax.random.PRNGKey(s), spec.num_clients,
                         spec.classes, alpha=spec.alpha, sigma0=spec.sigma0,
                         delta=spec.delta)[0]
        for s in spec.seeds])


def run_cell(spec: SweepSpec, algo: str, scheme: str, *,
             metric_keys=("loss", "num_active")) -> CellResult:
    """Run one (algo, scheme) cell: S seeds in one vmapped program."""
    task = get_task(spec)
    fed = spec.cell_config(algo, scheme)
    runner = _runner_for(spec, fed, task, metric_keys)
    keys = stack_seed_keys(spec.seeds)
    p_base = seed_base_probs(spec)
    states, out = runner(keys, p_base)
    if "evals" in out:
        test_acc = np.asarray(out["evals"])
        rounds_at = eval_rounds(spec.rounds, spec.eval_every)
    else:
        test_acc = np.asarray(jax.vmap(task.eval_test)(states.server))[:, None]
        rounds_at = [spec.rounds]
    train_acc = np.asarray(jax.vmap(task.eval_train)(states.server))
    mets = {k: np.asarray(v) for k, v in out["metrics"].items()}
    return CellResult(
        algo=algo, scheme=scheme, seeds=tuple(spec.seeds), rounds=spec.rounds,
        eval_rounds=rounds_at, test_acc=test_acc, train_acc=train_acc,
        loss=mets.get("loss", np.zeros((len(spec.seeds), 0))),
        num_active=mets.get("num_active", np.zeros((len(spec.seeds), 0))))


def run_sweep(spec: SweepSpec, *, store: Optional[ResultsStore] = None,
              suite: str = "sweep",
              metric_keys=("loss", "num_active")) -> List[CellResult]:
    """Execute the full grid; optionally append every cell to ``store``."""
    # validate every cell upfront — a typo in the last algorithm must not
    # surface as a KeyError after earlier cells ran for minutes
    for scheme in spec.schemes:
        for algo in spec.algorithms:
            spec.cell_config(algo, scheme)
    cells = []
    for scheme in spec.schemes:
        for algo in spec.algorithms:
            cell = run_cell(spec, algo, scheme, metric_keys=metric_keys)
            cells.append(cell)
            if store is not None:
                store.append(
                    {"suite": suite, "algo": algo, "scheme": scheme,
                     "seeds": list(spec.seeds), "rounds": spec.rounds,
                     "eval_every": spec.eval_every,
                     "spec": dataclasses.asdict(spec),
                     "eval_rounds": cell.eval_rounds,
                     "summary": cell.summary()},
                    arrays={"test_acc": cell.test_acc,
                            "train_acc": cell.train_acc,
                            "loss": cell.loss,
                            "num_active": cell.num_active})
    return cells
