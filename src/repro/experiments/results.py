"""Append-only results store for experiment sweeps.

Each appended record is one JSON line in ``<root>/results.jsonl`` — scalar
metadata and summaries only — and (optionally) one ``.npz`` file under
``<root>/arrays/`` holding the record's array payloads (per-seed trajectories,
final accuracies, ...). Records are keyed by a monotonically increasing
``record_id`` and stamped with the repo's git SHA, so a sweep re-run after a
code change appends new rows instead of silently overwriting old ones; the
CSV printing the paper-table benchmarks used to do is now a *view* over this
store, not the storage itself.

The format is deliberately dependency-free: JSONL for greppable metadata,
``numpy.savez_compressed`` for arrays.

Stores from different sessions/machines union with ``ResultsStore.merge``
(dedup by ``cell_key``, later stores win), also exposed as a CLI::

    python -m repro.experiments.results merge --out merged store_a store_b

which reports the merged rows grouped by their recorded git SHA.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import uuid
from typing import Any, Dict, Iterable, List, Optional, Union

import numpy as np


def git_sha(cwd: Optional[str] = None) -> str:
    """Short git SHA of the repo containing ``cwd`` (or this file); falls back
    to ``"unknown"`` outside a git checkout (e.g. an installed wheel)."""
    if cwd is None:
        cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def summarize(values, confidence: str = "ci95") -> Dict[str, float]:
    """Mean / std / normal-approx 95% CI half-width over a 1-D seed axis.

    NaN entries are dropped before summarizing (``n`` counts the finite
    values): variable-length trajectories — e.g. early-pruned search
    candidates pooled with full-budget ones — are NaN-padded to a common
    width, and the padding must not poison the statistics."""
    v = np.asarray(values, np.float64).ravel()
    v = v[~np.isnan(v)]
    n = int(v.size)
    mean = float(v.mean()) if n else float("nan")
    std = float(v.std(ddof=1)) if n > 1 else 0.0
    half = 1.96 * std / math.sqrt(n) if n > 1 else 0.0
    return {"mean": mean, "std": std, "n": n, confidence: half}


def buffered_summary(commit: np.ndarray,
                     commit_staleness: np.ndarray) -> Dict[str, Any]:
    """Per-seed summaries of a buffered cell's commit trace.

    ``commit [S, K]`` is the per-round commit indicator (0/1), and
    ``commit_staleness [S, K]`` the mean buffered-contribution age at each
    commit (0 on non-commit rounds). Returns ``commits`` (commits per
    trajectory) and ``commit_staleness`` (per-seed commit-weighted mean age)
    summarized over seeds — the staleness/participation fields the sweep
    store records for buffered strategies.
    """
    commit = np.asarray(commit, np.float64)
    stale = np.asarray(commit_staleness, np.float64)
    n_commits = commit.sum(axis=1)
    mean_stale = (stale * commit).sum(axis=1) / np.maximum(n_commits, 1.0)
    return {"commits": summarize(n_commits),
            "commit_staleness": summarize(mean_stale)}


# SweepSpec fields (beyond rounds/eval_every, recorded top-level) that change
# what a cell measures; folded into cell_key from the record's "spec" dict so
# e.g. an m=32 run never deduplicates against an m=100 run of the same suite.
_PROTOCOL_FIELDS = ("num_clients", "local_steps", "batch_size", "data_seed",
                    "dim", "classes", "hidden", "n_per_class", "n_train",
                    "per_client", "fed_overrides", "cohort_size")


def _hashable(v):
    if isinstance(v, (list, tuple)):
        return tuple(_hashable(x) for x in v)
    return v


def cell_key(record: Dict[str, Any]) -> tuple:
    """Canonical identity of a record's grid cell: suite, algorithm, scheme,
    seed set, round protocol, hyperparameter coordinates, and the spec's
    protocol fields (client count, dataset/model shape, overrides). Two
    records with equal ``cell_key`` measure the same thing (possibly from
    different sessions / code revisions) and deduplicate under
    ``ResultsStore.merge``.
    """
    spec = record.get("spec") or {}
    # adaptive-search rows carry a budget coordinate: a candidate pruned at
    # rung 1 and the same point run to the full budget measure different
    # things, so the (rung, budget_rounds) pair joins the identity. Records
    # without a "search" dict normalize to () — legacy keys are unchanged.
    search = record.get("search") or {}
    hp = record.get("hparams")
    if hp is None:
        # legacy (pre-hyperparameter-axis) records: the swept value lives
        # only in the spec's scalar knobs — fold those in so e.g. old fig8
        # delta-ablation rows don't collapse into one cell
        hp = {f: spec[f] for f in ("lr", "gamma", "alpha", "sigma0", "delta")
              if f in spec}
    return (record.get("suite"), record.get("algo"), record.get("scheme"),
            # strategy-axis coordinate; records predating the axis carry no
            # field and normalize to "sync" (they ARE synchronous cells)
            record.get("strategy") or "sync",
            _hashable(record.get("seeds")), record.get("rounds"),
            record.get("eval_every"),
            tuple(sorted((k, _hashable(v)) for k, v in hp.items())),
            tuple((f, _hashable(spec.get(f))) for f in _PROTOCOL_FIELDS
                  if f in spec),
            tuple((k, _hashable(search.get(k)))
                  for k in ("rung", "budget_rounds") if k in search))


def _jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (np.ndarray,)):
        return x.tolist()
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


class ResultsStore:
    """Append-only JSONL + npz store rooted at a directory.

    >>> store = ResultsStore("benchmarks/out/sweeps")
    >>> rec = store.append({"suite": "table1", "algo": "fedpbc"},
    ...                    arrays={"test_acc": acc})   # acc: [S, E]
    >>> rows = store.records(suite="table1")
    >>> store.load_arrays(rows[-1])["test_acc"]
    """

    def __init__(self, root: str):
        self.root = root
        self.arrays_dir = os.path.join(root, "arrays")
        self.path = os.path.join(root, "results.jsonl")
        os.makedirs(self.arrays_dir, exist_ok=True)
        # cached (line count, file size) as of this handle's last look; the
        # size check invalidates the cache whenever ANOTHER handle grew the
        # file, so interleaved same-process handles keep ids unique while
        # bulk writers like merge() stay O(N) instead of re-counting per row
        self._count: Optional[int] = None
        self._size: int = -1

    def _file_size(self) -> int:
        try:
            return os.path.getsize(self.path)
        except OSError:
            return 0

    def _next_id(self) -> int:
        size = self._file_size()
        if self._count is None or size != self._size:
            if not os.path.exists(self.path):
                self._count = 0
            else:
                with open(self.path) as f:
                    self._count = sum(1 for line in f if line.strip())
            self._size = size
        return self._count

    def append(self, record: Dict[str, Any],
               arrays: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Write one record; returns it with ``record_id`` / ``git_sha`` /
        ``arrays`` (npz relpath) fields filled in."""
        rec = dict(record)
        rec["record_id"] = self._next_id()
        rec.setdefault("git_sha", git_sha())
        if arrays:
            # record_id is derived from the line count, so two processes
            # appending concurrently can both claim id N; the random suffix
            # keeps their array payloads from clobbering each other (each
            # record references its own npz)
            rel = os.path.join(
                "arrays", f"r{rec['record_id']:06d}-{uuid.uuid4().hex[:8]}.npz")
            np.savez_compressed(
                os.path.join(self.root, rel),
                **{k: np.asarray(v) for k, v in arrays.items()})
            rec["arrays"] = rel
        line = json.dumps(_jsonable(rec), sort_keys=True)
        with open(self.path, "a") as f:
            f.write(line + "\n")
        self._count = rec["record_id"] + 1
        self._size = self._file_size()
        return rec

    def records(self, **filters) -> List[Dict[str, Any]]:
        """All records whose top-level fields equal ``filters`` (e.g.
        ``records(suite="table1", algo="fedpbc")``), in append order."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                rec = json.loads(line)
                if all(rec.get(k) == v for k, v in filters.items()):
                    out.append(rec)
        return out

    def load_arrays(self, record: Dict[str, Any]) -> Dict[str, np.ndarray]:
        rel = record.get("arrays")
        if not rel:
            return {}
        with np.load(os.path.join(self.root, rel)) as z:
            return {k: z[k] for k in z.files}

    @classmethod
    def merge(cls, dest_root: str,
              *stores: Union[str, "ResultsStore"]) -> "ResultsStore":
        """Union several stores into a fresh store at ``dest_root``.

        Records are deduplicated by ``cell_key``: when two stores hold the
        same cell, the LAST one (in argument order, then append order) wins —
        so merging an old session's store before a re-run's store keeps the
        re-run. Surviving records are re-appended in their original order
        with fresh ``record_id``s (the source id is kept as
        ``source_record_id``); array payloads are copied; the recorded
        ``git_sha`` of each source row is preserved, so a merged store can
        group rows by the code revision that produced them.

        A record whose npz payload is missing on disk (e.g. a partially
        copied store) is kept with its metadata and a warning instead of
        aborting the merge halfway.

        ``dest_root`` must be a FRESH (empty) store: merging onto existing
        rows would bypass dedup and silently duplicate cells, so a non-empty
        destination is refused — include it as a *source* instead
        (``merge(new_dir, old_dest, more...)``).
        """
        import sys

        dest_jsonl = os.path.join(dest_root, "results.jsonl")
        if os.path.exists(dest_jsonl) and os.path.getsize(dest_jsonl) > 0:
            raise ValueError(
                f"merge destination {dest_root!r} already has records; "
                f"merge into a fresh directory (pass the old destination as "
                f"a source to re-merge)")
        # a typo'd source path must fail loudly — the constructor would
        # happily mkdir an empty store there and contribute zero rows
        for s in stores:
            if not isinstance(s, cls) and not os.path.exists(
                    os.path.join(s, "results.jsonl")):
                raise FileNotFoundError(
                    f"source store {s!r} has no results.jsonl")
        opened = [s if isinstance(s, cls) else cls(s) for s in stores]
        rows: List[tuple] = []          # (key, record, source store)
        for store in opened:
            for rec in store.records():
                rows.append((cell_key(rec), rec, store))
        last = {key: i for i, (key, _, _) in enumerate(rows)}
        merged = cls(dest_root)
        for i, (key, rec, store) in enumerate(rows):
            if last[key] != i:
                continue
            try:
                arrays = store.load_arrays(rec)
            except OSError as e:
                print(f"warning: skipping arrays of record "
                      f"{rec.get('record_id')} in {store.root}: {e}",
                      file=sys.stderr)
                arrays = {}
            out = {k: v for k, v in rec.items()
                   if k not in ("record_id", "arrays")}
            out["source_record_id"] = rec.get("record_id")
            merged.append(out, arrays=arrays or None)
        return merged


def group_by_sha(records: Iterable[Dict[str, Any]]) -> Dict[str, List[Dict[str, Any]]]:
    """Rows grouped by their recorded git SHA, preserving append order."""
    out: Dict[str, List[Dict[str, Any]]] = {}
    for rec in records:
        out.setdefault(rec.get("git_sha", "unknown"), []).append(rec)
    return out


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.results",
        description="Results-store tools.")
    sub = ap.add_subparsers(dest="cmd", required=True)
    mp = sub.add_parser(
        "merge", help="union stores into --out, dedup by cell key "
                      "(later stores win), report rows grouped by git SHA")
    mp.add_argument("stores", nargs="+", help="source store directories")
    mp.add_argument("--out", required=True, help="destination store directory")
    args = ap.parse_args(argv)

    if args.cmd == "merge":
        merged = ResultsStore.merge(args.out, *args.stores)
        rows = merged.records()
        print(f"merged {len(args.stores)} stores -> {merged.path} "
              f"({len(rows)} rows)")
        for sha, group in group_by_sha(rows).items():
            suites: Dict[str, int] = {}
            for rec in group:
                suites[rec.get("suite", "?")] = \
                    suites.get(rec.get("suite", "?"), 0) + 1
            detail = ", ".join(f"{s}={n}" for s, n in sorted(suites.items()))
            print(f"  git {sha}: {len(group)} rows ({detail})")


if __name__ == "__main__":
    main()
