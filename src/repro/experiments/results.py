"""Append-only results store for experiment sweeps.

Each appended record is one JSON line in ``<root>/results.jsonl`` — scalar
metadata and summaries only — and (optionally) one ``.npz`` file under
``<root>/arrays/`` holding the record's array payloads (per-seed trajectories,
final accuracies, ...). Records are keyed by a monotonically increasing
``record_id`` and stamped with the repo's git SHA, so a sweep re-run after a
code change appends new rows instead of silently overwriting old ones; the
CSV printing the paper-table benchmarks used to do is now a *view* over this
store, not the storage itself.

The format is deliberately dependency-free: JSONL for greppable metadata,
``numpy.savez_compressed`` for arrays.
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import uuid
from typing import Any, Dict, List, Optional

import numpy as np


def git_sha(cwd: Optional[str] = None) -> str:
    """Short git SHA of the repo containing ``cwd`` (or this file); falls back
    to ``"unknown"`` outside a git checkout (e.g. an installed wheel)."""
    if cwd is None:
        cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=cwd,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def summarize(values, confidence: str = "ci95") -> Dict[str, float]:
    """Mean / std / normal-approx 95% CI half-width over a 1-D seed axis."""
    v = np.asarray(values, np.float64).ravel()
    n = int(v.size)
    mean = float(v.mean()) if n else float("nan")
    std = float(v.std(ddof=1)) if n > 1 else 0.0
    half = 1.96 * std / math.sqrt(n) if n > 1 else 0.0
    return {"mean": mean, "std": std, "n": n, confidence: half}


def _jsonable(x):
    if isinstance(x, (np.integer,)):
        return int(x)
    if isinstance(x, (np.floating,)):
        return float(x)
    if isinstance(x, (np.ndarray,)):
        return x.tolist()
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    return x


class ResultsStore:
    """Append-only JSONL + npz store rooted at a directory.

    >>> store = ResultsStore("benchmarks/out/sweeps")
    >>> rec = store.append({"suite": "table1", "algo": "fedpbc"},
    ...                    arrays={"test_acc": acc})   # acc: [S, E]
    >>> rows = store.records(suite="table1")
    >>> store.load_arrays(rows[-1])["test_acc"]
    """

    def __init__(self, root: str):
        self.root = root
        self.arrays_dir = os.path.join(root, "arrays")
        self.path = os.path.join(root, "results.jsonl")
        os.makedirs(self.arrays_dir, exist_ok=True)

    def _next_id(self) -> int:
        if not os.path.exists(self.path):
            return 0
        with open(self.path) as f:
            return sum(1 for line in f if line.strip())

    def append(self, record: Dict[str, Any],
               arrays: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Write one record; returns it with ``record_id`` / ``git_sha`` /
        ``arrays`` (npz relpath) fields filled in."""
        rec = dict(record)
        rec["record_id"] = self._next_id()
        rec.setdefault("git_sha", git_sha())
        if arrays:
            # record_id is derived from the line count, so two processes
            # appending concurrently can both claim id N; the random suffix
            # keeps their array payloads from clobbering each other (each
            # record references its own npz)
            rel = os.path.join(
                "arrays", f"r{rec['record_id']:06d}-{uuid.uuid4().hex[:8]}.npz")
            np.savez_compressed(
                os.path.join(self.root, rel),
                **{k: np.asarray(v) for k, v in arrays.items()})
            rec["arrays"] = rel
        line = json.dumps(_jsonable(rec), sort_keys=True)
        with open(self.path, "a") as f:
            f.write(line + "\n")
        return rec

    def records(self, **filters) -> List[Dict[str, Any]]:
        """All records whose top-level fields equal ``filters`` (e.g.
        ``records(suite="table1", algo="fedpbc")``), in append order."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                if not line.strip():
                    continue
                rec = json.loads(line)
                if all(rec.get(k) == v for k, v in filters.items()):
                    out.append(rec)
        return out

    def load_arrays(self, record: Dict[str, Any]) -> Dict[str, np.ndarray]:
        rel = record.get("arrays")
        if not rel:
            return {}
        with np.load(os.path.join(self.root, rel)) as z:
            return {k: z[k] for k in z.files}
