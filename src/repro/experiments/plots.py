"""Figure-style curve exports straight from a results store — no re-runs.

The table/figure benchmarks already persist per-seed trajectories
(``test_acc [S, E]``, ``loss [S, K]``) next to every record; this module
turns them into the fig-3 / fig-8 style curve files (mean ± normal-approx
95% CI over the seed axis) without executing a single round:

    from repro.experiments.plots import export_curves
    export_curves(ResultsStore("benchmarks/out/sweeps"), "benchmarks/out/curves",
                  suite="table1")

or::

    python -m repro.experiments.plots --store benchmarks/out/sweeps \
        --out benchmarks/out/curves --suite fig8_alpha

Records sharing a curve identity (same suite/algo/scheme/rounds/hparams but
e.g. different seed batches from different sessions) are pooled along the
seed axis before summarizing. Output is dependency-free CSV: one
``<slug>_acc.csv`` (round, mean, std, ci95, n_seeds) and one
``<slug>_loss.csv`` per curve.
"""
from __future__ import annotations

import hashlib
import math
import os
from typing import Any, Dict, List

import numpy as np

from repro.experiments.results import ResultsStore, cell_key


def _curve_key(record: Dict[str, Any]) -> tuple:
    """Curve identity: ``cell_key`` minus the seed set, so records of
    different seed batches pool along the seed axis while every
    protocol-distinguishing field still separates curves."""
    (suite, algo, scheme, strategy, _seeds, rounds, ee, hp,
     proto, search) = cell_key(record)
    return (suite, algo, scheme, strategy, rounds, ee, hp, proto, search)


def _slug(key: tuple) -> str:
    """Filename for one curve. Every component of ``_curve_key`` must reach
    the name or distinct curves would overwrite each other's CSVs: the
    human-readable parts come first (hparams rendered at %g precision for
    the eye), and the EXACT hparam + protocol values are folded into a short
    digest suffix so curves differing only beyond display precision (e.g.
    logspace-generated lrs) still get distinct files."""
    suite, algo, scheme, strategy, rounds, ee, hp, proto, search = key
    parts = [str(suite), str(algo), str(scheme)]
    # synchronous cells keep their historical filenames; buffered-strategy
    # curves get the strategy name as one more distinguishing part
    if strategy != "sync":
        parts.append(str(strategy))
    parts += [f"r{rounds}", f"e{ee}"]
    parts += [f"{k}{v:g}" for k, v in hp]
    # adaptive-search budget coordinate; non-search curves (search == ())
    # keep their historical filenames and digests
    for k, v in search:
        parts.append(f"{'rung' if k == 'rung' else 'b'}{v:g}")
    if hp or proto or search:
        parts.append("p" + hashlib.md5(
            repr((hp, proto, search) if search
                 else (hp, proto)).encode()).hexdigest()[:6])
    return "-".join(p.replace("/", "_").replace(" ", "") for p in parts)


def _summarize_rows(a: np.ndarray):
    """[S, T] -> (mean [T], std [T], ci95 [T], n [T]) over the seed axis,
    NaN-aware: pooled rows of different lengths are NaN-padded, so every
    per-round statistic is computed over the seeds that actually reached
    that round (``n`` is the per-round finite count)."""
    valid = ~np.isnan(a)
    n = valid.sum(axis=0)
    mean = np.where(n > 0, np.nansum(a, axis=0) / np.maximum(n, 1), np.nan)
    d = np.where(valid, a - mean, 0.0)
    var = np.where(n > 1, (d ** 2).sum(axis=0) / np.maximum(n - 1, 1), 0.0)
    std = np.sqrt(var)
    ci95 = np.where(n > 1, 1.96 * std / np.sqrt(np.maximum(n, 1)), 0.0)
    return mean, std, ci95, n


def _write_curve(path: str, xs, a: np.ndarray) -> str:
    mean, std, ci95, n = _summarize_rows(a)
    with open(path, "w") as f:
        f.write("round,mean,std,ci95,n_seeds\n")
        for x, m, sd, ci, k in zip(xs, mean, std, ci95, n):
            f.write(f"{int(x)},{m:.6f},{sd:.6f},{ci:.6f},{int(k)}\n")
    return path


def _pool_seed_rows(recs, payloads, name) -> "np.ndarray | None":
    """Pool one array field across a curve's records along the seed axis,
    deduplicating by seed: when two records carry the same seed (a later
    session re-ran a superset batch), the later record's row wins — simple
    concatenation would double-count the shared seeds and understate the CI.
    Records without a usable ``seeds`` list contribute all rows under
    synthetic never-colliding ids. Rows of different lengths (truncated
    early-pruned trajectories pooled with full ones) are right-padded with
    NaN to the longest row; the summaries mask the padding out."""
    rows: Dict[Any, np.ndarray] = {}
    for i, (rec, p) in enumerate(zip(recs, payloads)):
        arr = p.get(name)
        if arr is None or arr.size == 0:
            continue
        seeds = rec.get("seeds")
        if not isinstance(seeds, list) or len(seeds) != arr.shape[0]:
            seeds = [("anon", i, j) for j in range(arr.shape[0])]
        for s, row in zip(seeds, arr):
            rows[_hashable_seed(s)] = row
    if not rows:
        return None
    width = max(r.shape[0] for r in rows.values())
    return np.stack([
        np.pad(r.astype(np.float64), (0, width - r.shape[0]),
               constant_values=np.nan) if r.shape[0] < width else r
        for r in rows.values()])


def _hashable_seed(s):
    return tuple(s) if isinstance(s, list) else s


def export_curves(store: ResultsStore, out_dir: str,
                  **filters) -> List[str]:
    """Emit accuracy/loss curve CSVs for every curve in ``store`` matching
    ``filters`` (same semantics as ``store.records``); returns the written
    paths. Records without array payloads — including records whose npz file
    is missing on disk (partially copied store) — are skipped with a warning.

    The store is append-only, so a re-run of the same cell appends a second
    record with the same ``cell_key``: only the LATEST record per cell is
    used (re-runs supersede), while records of different seed batches pool
    along the seed axis (per-seed dedup, later records win on overlap).

    A store with NO records matching ``filters`` raises ``ValueError`` (an
    empty/missing store or an over-narrow filter is a caller mistake — a
    silent zero-file export would just move the confusion downstream)."""
    import sys

    # latest record per cell over ALL records — a later arrays-less record
    # (e.g. merge kept its metadata after a lost npz) must SUPERSEDE an older
    # run, not let the older run's stale arrays masquerade as current
    latest: Dict[tuple, Dict[str, Any]] = {}
    for rec in store.records(**filters):
        latest[cell_key(rec)] = rec     # later append wins
    if not latest:
        what = (f"matching filters {filters}" if filters
                else "(empty or missing store)")
        raise ValueError(
            f"no records to export from {store.path} {what}")
    groups: Dict[tuple, List[Dict[str, Any]]] = {}
    for rec in latest.values():
        if not rec.get("arrays"):
            print(f"warning: skipping record {rec.get('record_id')} "
                  f"(no array payload)", file=sys.stderr)
            continue
        groups.setdefault(_curve_key(rec), []).append(rec)
    os.makedirs(out_dir, exist_ok=True)
    written: List[str] = []
    for key, group in groups.items():
        recs, payloads = [], []
        # append order (record_id) so later re-runs win per-seed dedup
        for rec in sorted(group, key=lambda r: r.get("record_id", 0)):
            try:
                payloads.append(store.load_arrays(rec))
                recs.append(rec)
            except OSError as e:
                print(f"warning: skipping record {rec.get('record_id')} "
                      f"(missing arrays): {e}", file=sys.stderr)
        if not recs:
            continue
        slug = _slug(key)
        acc = _pool_seed_rows(recs, payloads, "test_acc")
        if acc is not None:
            # pooled width = the LONGEST record's trajectory; take the eval
            # axis from whichever record spans it (truncated rows are
            # NaN-padded up to it)
            rounds_at = max(
                (r.get("eval_rounds") for r in recs
                 if isinstance(r.get("eval_rounds"), list)),
                key=len, default=None)
            if rounds_at is None or len(rounds_at) != acc.shape[1]:
                rounds_at = list(range(1, acc.shape[1] + 1))
            written.append(_write_curve(
                os.path.join(out_dir, f"{slug}_acc.csv"), rounds_at, acc))
        loss = _pool_seed_rows(recs, payloads, "loss")
        if loss is not None:
            written.append(_write_curve(
                os.path.join(out_dir, f"{slug}_loss.csv"),
                range(1, loss.shape[1] + 1), loss))
    return written


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.experiments.plots",
        description="Export mean±CI curve CSVs from a results store "
                    "(no cells are re-run).")
    ap.add_argument("--store", required=True, help="results-store directory")
    ap.add_argument("--out", required=True, help="output directory for CSVs")
    ap.add_argument("--suite", default=None, help="only this suite tag")
    args = ap.parse_args(argv)
    filters = {"suite": args.suite} if args.suite else {}
    written = export_curves(ResultsStore(args.store), args.out, **filters)
    for path in written:
        print(path)
    print(f"# {len(written)} curve files -> {args.out}")


if __name__ == "__main__":
    main()
