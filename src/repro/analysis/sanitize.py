"""Runtime sanitizers: the dynamic half of the trace-discipline gate.

``CompileSanitizer`` / ``assert_no_new_compiles`` generalize the ad-hoc
``_cache_size()`` assertions the test suite grew per file: the sweep
engine's contract is ONE compiled (init, scan) pair per (family x scheme)
runner no matter how many hparam points / seeds / strategies ride the
traced axes, and these helpers pin it in one idiom.

Two modes, one entry point::

    # exact-total (the test-suite pin): check immediately
    assert_no_new_compiles(run.init_batch, run.scan_batch, expect_total=1)

    # delta (wrap a region that must not retrace): context manager
    with assert_no_new_compiles(run.scan_batch):
        run.scan_batch(more_points)     # new hparam values are free

Both modes no-op gracefully when a function does not expose jit's
``_cache_size`` introspection (e.g. a plain python callable or a jax
version without it) — mirroring the ``hasattr`` guards they replace.

``DonationSanitizer`` checks that buffers handed to ``donate_argnums``
positions were actually consumed (``is_deleted``), skipping on backends
that ignore donation (CPU).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax


def cache_size(fn: Any) -> Optional[int]:
    """jit-cache entry count for ``fn``, or None when the introspection
    hook is unavailable."""
    probe = getattr(fn, "_cache_size", None)
    return probe() if callable(probe) else None


class CompileSanitizer:
    """Pins the jit-cache growth of one or more compiled callables.

    ``expect_total=N``: every function's cache must hold exactly N entries
    at check time.  ``expect_total=None``: at most ``max_new`` entries may
    appear between construction (snapshot) and check — use as a context
    manager around a region that must not retrace.
    """

    def __init__(self, *fns: Any, expect_total: Optional[int] = None,
                 max_new: int = 0, label: str = ""):
        if not fns:
            raise ValueError("CompileSanitizer needs at least one callable")
        self.fns = fns
        self.expect_total = expect_total
        self.max_new = max_new
        self.label = label
        self._start: List[Optional[int]] = [cache_size(f) for f in fns]

    @property
    def has_introspection(self) -> bool:
        """True when every wrapped callable exposes ``_cache_size``."""
        return all(s is not None for s in self._start)

    def check(self) -> "CompileSanitizer":
        tag = f" [{self.label}]" if self.label else ""
        for fn, start in zip(self.fns, self._start):
            now = cache_size(fn)
            if now is None:
                continue            # no introspection: nothing to pin
            name = getattr(fn, "__name__", repr(fn))
            if self.expect_total is not None:
                if now != self.expect_total:
                    raise AssertionError(
                        f"compile sanitizer{tag}: {name} holds {now} jit "
                        f"cache entries, expected exactly "
                        f"{self.expect_total} — a traced axis leaked into "
                        f"the compile key")
            else:
                grown = now - (start or 0)
                if grown > self.max_new:
                    raise AssertionError(
                        f"compile sanitizer{tag}: {name} gained {grown} "
                        f"jit cache entries (allowed {self.max_new}) — "
                        f"the guarded region retraced")
        return self

    def __enter__(self) -> "CompileSanitizer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.check()


def assert_no_new_compiles(*fns: Any, expect_total: Optional[int] = None,
                           max_new: int = 0,
                           label: str = "") -> CompileSanitizer:
    """One entry point for both compile-counter idioms (see module doc).

    With ``expect_total`` the check runs immediately; without it the
    returned sanitizer snapshots now and checks on ``with``-exit (or an
    explicit ``.check()``).
    """
    sanitizer = CompileSanitizer(*fns, expect_total=expect_total,
                                 max_new=max_new, label=label)
    if expect_total is not None:
        sanitizer.check()
    return sanitizer


# ---------------------------------------------------------------------------
# Donation
# ---------------------------------------------------------------------------


def donation_honored() -> bool:
    """Whether this backend actually consumes donated buffers (CPU ignores
    donation, so donated args stay live there by design)."""
    return jax.default_backend() != "cpu"


class DonationSanitizer:
    """Asserts that operands handed to ``donate_argnums`` positions were
    consumed by the call::

        with DonationSanitizer(state, batch):
            state2, out = run(state, batch)

    On exit every array leaf of the wrapped operands must be deleted
    (``x.is_deleted()``).  Skips silently where donation is ignored
    (CPU) unless ``strict=True``.
    """

    def __init__(self, *donated: Any, strict: bool = False):
        self.leaves = [x for x in jax.tree_util.tree_leaves(donated)
                       if hasattr(x, "is_deleted")]
        self.strict = strict

    def live_leaves(self) -> Sequence[Any]:
        return [x for x in self.leaves if not x.is_deleted()]

    def assert_donated(self) -> None:
        if not donation_honored() and not self.strict:
            return
        live = self.live_leaves()
        if live:
            shapes = [getattr(x, "shape", "?") for x in live[:4]]
            raise AssertionError(
                f"donation sanitizer: {len(live)}/{len(self.leaves)} "
                f"donated leaves still live after the call (first shapes "
                f"{shapes}) — donate_argnums did not consume them")

    def __enter__(self) -> "DonationSanitizer":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.assert_donated()
