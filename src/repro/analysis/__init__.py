"""Trace-discipline tooling: tracelint (static) + sanitizers (runtime).

Static half (stdlib-only, safe without jax installed)::

    python -m repro.analysis.lint src benchmarks --baseline .tracelint-baseline.json

Runtime half (imports jax lazily — ``from repro.analysis.sanitize import
assert_no_new_compiles``).
"""
from repro.analysis.rules import RULES, Finding, Rule

__all__ = ["RULES", "Finding", "Rule", "lint_paths", "lint_text",
           "assert_no_new_compiles", "CompileSanitizer",
           "DonationSanitizer"]


def __getattr__(name):
    # keep `import repro.analysis` jax-free; pull the heavy halves on demand
    if name in {"lint_paths", "lint_text", "lint_file", "main"}:
        from repro.analysis import lint
        return getattr(lint, name)
    if name in {"assert_no_new_compiles", "CompileSanitizer",
                "DonationSanitizer", "cache_size", "donation_honored"}:
        from repro.analysis import sanitize
        return getattr(sanitize, name)
    raise AttributeError(name)
