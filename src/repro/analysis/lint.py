"""tracelint — AST static analysis for JAX trace discipline.

Pure-stdlib (no jax import): cheap enough to run as the first CI job.

The analysis is module-local and deliberately conservative in both
directions: a *traced context* is a function the module's own text provably
hands to a tracer (decorated with / passed to jit, vmap, pmap, grad,
scan, fori_loop, while_loop, cond, switch, pallas_call — or any function
lexically nested in one), plus the repo's ``round_fn`` convention, which is
how the executor's round bodies travel (``core.federated`` attaches them to
the scan by closure, invisibly to a structural scan).  Inside a traced
context the taint sources are the function's own parameters and the params
of traced ancestors; values reached only through ``.shape``/``.ndim``/
``.dtype`` or ``len``/``isinstance`` are compile-time constants under
tracing and are exempt, as is the ``x is None`` optional-argument pattern
on a bare parameter (a static trace signature, not data-dependent control
flow) — but ``x.attr is None`` is NOT exempt: reaching into an argument's
internals belongs at build time.

CLI::

    python -m repro.analysis.lint src benchmarks \
        --baseline .tracelint-baseline.json [--json] [--update-baseline]

Exit status is 0 iff every finding is grandfathered by the baseline (or
there are none); any *new* finding exits 1.
"""
from __future__ import annotations

import argparse
import ast
import json
import re
import sys
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis import baseline as baseline_lib
from repro.analysis.rules import RULES, Finding, render_rule_table

# ---------------------------------------------------------------------------
# Traced-context discovery
# ---------------------------------------------------------------------------

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)

#: wrapper names whose presence in a decorator marks the function traced
TRACE_WRAPPERS = {"jit", "vmap", "pmap", "grad", "value_and_grad",
                  "checkpoint", "remat", "pallas_call", "custom_vjp",
                  "custom_jvp"}

#: call name -> positional indices holding traced callables
TRACED_CALLEE_ARGS: Dict[str, Tuple[int, ...]] = {
    "scan": (0,), "fori_loop": (2,), "while_loop": (0, 1),
    "cond": (1, 2), "switch": (1,),
    "jit": (0,), "vmap": (0,), "pmap": (0,),
    "grad": (0,), "value_and_grad": (0,),
    "checkpoint": (0,), "remat": (0,), "pallas_call": (0,),
}

#: attribute accesses that yield compile-time constants under tracing
SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "aval", "sharding"}

#: calls whose results are static regardless of traced arguments
STATIC_CALLS = {"len", "isinstance", "type", "hasattr", "getattr",
                "eval_shape", "tree_structure"}

HOST_SYNC_METHODS = {"item", "block_until_ready", "tolist"}
NUMPY_ALIASES = {"np", "numpy", "onp"}
HPARAM_ATTRS = {"lr", "lrs", "gamma", "alpha", "sigma0", "delta"}
CANON_ZEROED = {"alpha", "sigma0", "delta", "gamma"}
PYTREE_ANN = re.compile(r"\b(?:jnp\.ndarray|jax\.Array|Array|ArrayLike"
                        r"|Pytree|PyTree)\b")
RUNNER_CACHE_NAME = re.compile(r"^_?[A-Z_]*RUNNER_CACHE[A-Z_]*$")
REDUCTION_CALLS = {"dot", "dot_general", "matmul", "einsum", "sum",
                   "cumsum"}

SUPPRESS_RE = re.compile(
    r"#\s*tracelint:\s*disable=([A-Z0-9,\s]+?)\s*(?:--\s*(\S.*))?$")


def _names(expr: ast.AST) -> Set[str]:
    """All Name ids and Attribute attrs in ``expr`` (a loose identifier
    bag: `jax.lax.scan` -> {'jax', 'lax', 'scan'})."""
    out: Set[str] = set()
    for node in ast.walk(expr):
        if isinstance(node, ast.Name):
            out.add(node.id)
        elif isinstance(node, ast.Attribute):
            out.add(node.attr)
    return out


def _callable_refs(expr: ast.AST) -> Tuple[List[ast.AST], List[str]]:
    """Resolve a callable-position argument to (lambda nodes, names),
    looking through functools.partial and callable lists."""
    if isinstance(expr, ast.Lambda):
        return [expr], []
    if isinstance(expr, ast.Name):
        return [], [expr.id]
    if isinstance(expr, ast.Call) and "partial" in _names(expr.func) \
            and expr.args:
        return _callable_refs(expr.args[0])
    if isinstance(expr, (ast.List, ast.Tuple)):
        nodes: List[ast.AST] = []
        names: List[str] = []
        for elt in expr.elts:
            n, m = _callable_refs(elt)
            nodes += n
            names += m
    else:
        nodes, names = [], []
    return nodes, names


class _Module:
    """Parsed module plus the maps every check needs."""

    def __init__(self, tree: ast.Module, path: str, source: str):
        self.tree = tree
        self.path = path
        self.lines = source.splitlines()
        self.parent: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
        self.functions = [n for n in ast.walk(tree)
                          if isinstance(n, _FuncNode)]
        self.defs_by_name: Dict[str, List[ast.AST]] = {}
        for fn in self.functions:
            if not isinstance(fn, ast.Lambda):
                self.defs_by_name.setdefault(fn.name, []).append(fn)
        self.traced_roots: Set[ast.AST] = set()
        self.kernel_roots: Set[ast.AST] = set()
        #: per-function params pinned static by jit (static_argnames/nums):
        #: compile constants, NOT taint sources
        self.static_params: Dict[ast.AST, Set[str]] = {}
        self._discover_traced()

    # -- traced-context discovery -------------------------------------
    def _discover_traced(self) -> None:
        for fn in self.functions:
            if not isinstance(fn, ast.Lambda):
                for dec in fn.decorator_list:
                    if _names(dec) & TRACE_WRAPPERS:
                        self.traced_roots.add(fn)
                        self._note_static_params(fn, dec)
                # the executor's round bodies travel by closure, invisibly
                # to a structural scan — catch them by convention (but not
                # their make_* factories)
                name = fn.name
                if name == "round_fn" or (name.endswith("_round_fn")
                                          and "make" not in name):
                    self.traced_roots.add(fn)
        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            fnames = _names(call.func)
            for key, positions in TRACED_CALLEE_ARGS.items():
                if key not in fnames:
                    continue
                for pos in positions:
                    if pos >= len(call.args):
                        continue
                    nodes, names = _callable_refs(call.args[pos])
                    for node in nodes:
                        self.traced_roots.add(node)
                        if key == "pallas_call":
                            self.kernel_roots.add(node)
                    for name in names:
                        for target in self.defs_by_name.get(name, []):
                            self.traced_roots.add(target)
                            if key == "pallas_call":
                                self.kernel_roots.add(target)
                            if key == "jit":
                                self._note_static_params(target, call)

    def _note_static_params(self, fn: ast.AST, wrapper: ast.AST) -> None:
        """Record params of ``fn`` pinned static by a jit wrapper
        (decorator or call site) via static_argnames / static_argnums."""
        if not isinstance(wrapper, ast.Call) \
                or "jit" not in _names(wrapper):
            return
        ordered = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        static: Set[str] = set()
        for kw in wrapper.keywords:
            if kw.arg == "static_argnames":
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) \
                            and isinstance(node.value, str):
                        static.add(node.value)
            elif kw.arg == "static_argnums":
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) \
                            and isinstance(node.value, int) \
                            and node.value < len(ordered):
                        static.add(ordered[node.value])
        if static:
            self.static_params.setdefault(fn, set()).update(static)

    def enclosing_fn(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent.get(node)
        while cur is not None and not isinstance(cur, _FuncNode):
            cur = self.parent.get(cur)
        return cur

    def fn_chain(self, fn: ast.AST) -> List[ast.AST]:
        """``fn`` plus its lexically enclosing functions, innermost first."""
        chain = [fn]
        cur = self.enclosing_fn(fn)
        while cur is not None:
            chain.append(cur)
            cur = self.enclosing_fn(cur)
        return chain

    def is_traced(self, fn: ast.AST) -> bool:
        return any(f in self.traced_roots for f in self.fn_chain(fn))

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""


# ---------------------------------------------------------------------------
# Taint: values derived from a traced function's parameters
# ---------------------------------------------------------------------------


def _param_names(fn: ast.AST) -> Set[str]:
    if isinstance(fn, ast.Lambda):
        args = fn.args
    else:
        args = fn.args
    names = {a.arg for a in
             args.posonlyargs + args.args + args.kwonlyargs}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    names.discard("self")
    names.discard("cls")
    return names


def _tainted_names_in(expr: ast.AST, tainted: Set[str]) -> Set[str]:
    """Tainted Name ids genuinely contributing to ``expr``: subtrees
    reached only through shape/dtype access, static builtins, or the
    ``param is None`` pattern do not count."""
    out: Set[str] = set()

    def visit(node: ast.AST) -> None:
        if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
            return
        if isinstance(node, ast.Call) and (_names(node.func) & STATIC_CALLS):
            return
        if isinstance(node, ast.Compare) \
                and isinstance(node.left, ast.Name) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in node.ops) \
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in node.comparators):
            return
        if isinstance(node, ast.Name) and node.id in tainted:
            out.add(node.id)
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(expr)
    return out


def _assign_targets(node: ast.AST) -> Set[str]:
    """Names (re)bound by an assignment-like statement."""
    out: Set[str] = set()

    def collect(t: ast.AST) -> None:
        if isinstance(t, ast.Name):
            out.add(t.id)
        elif isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                collect(e)
        elif isinstance(t, ast.Starred):
            collect(t.value)

    if isinstance(node, ast.Assign):
        for t in node.targets:
            collect(t)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        collect(node.target)
    elif isinstance(node, ast.For):
        collect(node.target)
    return out


def _function_taint(mod: _Module, fn: ast.AST) -> Set[str]:
    """Parameter taint for ``fn``, including params inherited from traced
    ancestors (closure reads of a *non*-traced factory are compile
    constants and stay clean), propagated through local assignments."""
    tainted: Set[str] = set()
    for f in mod.fn_chain(fn):
        if mod.is_traced(f):
            tainted |= _param_names(f) - mod.static_params.get(f, set())
    for _ in range(2):          # two passes reach chained assignments
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                value = node.value
                if value is not None and _tainted_names_in(value, tainted):
                    tainted |= _assign_targets(node)
            elif isinstance(node, ast.For):
                if _tainted_names_in(node.iter, tainted):
                    tainted |= _assign_targets(node)
    return tainted


# ---------------------------------------------------------------------------
# R001 / R002 — traced-context discipline
# ---------------------------------------------------------------------------


def _check_traced_contexts(mod: _Module, findings: List[Finding]) -> None:
    taint_cache: Dict[ast.AST, Set[str]] = {}

    def taint_for(fn: ast.AST) -> Set[str]:
        if fn not in taint_cache:
            taint_cache[fn] = _function_taint(mod, fn)
        return taint_cache[fn]

    for node in ast.walk(mod.tree):
        fn = mod.enclosing_fn(node)
        if fn is None or not mod.is_traced(fn):
            continue
        if isinstance(node, (ast.If, ast.While, ast.Assert)):
            names = _tainted_names_in(node.test, taint_for(fn))
            if names:
                findings.append(Finding(
                    mod.path, node.lineno, "R001",
                    f"Python {type(node).__name__.lower()} on traced "
                    f"value(s) {sorted(names)} inside a traced context; "
                    f"hoist to build time or use lax.cond/jnp.where",
                    mod.line_text(node.lineno)))
        elif isinstance(node, ast.Call):
            _check_host_sync(mod, node, taint_for(fn), findings)


def _check_host_sync(mod: _Module, call: ast.Call, tainted: Set[str],
                     findings: List[Finding]) -> None:
    func = call.func

    def hit(what: str) -> None:
        findings.append(Finding(
            mod.path, call.lineno, "R002",
            f"{what} inside a traced context (scan body / round fn / jit "
            f"body) forces a host sync or fails under tracing",
            mod.line_text(call.lineno)))

    if isinstance(func, ast.Attribute):
        if func.attr in HOST_SYNC_METHODS:
            hit(f".{func.attr}()")
            return
        if func.attr == "device_get":
            hit("jax.device_get")
            return
        if func.attr in {"asarray", "array"} \
                and isinstance(func.value, ast.Name) \
                and func.value.id in NUMPY_ALIASES \
                and call.args \
                and _tainted_names_in(call.args[0], tainted):
            hit(f"{func.value.id}.{func.attr} on a traced value")
            return
    elif isinstance(func, ast.Name):
        if func.id == "print":
            hit("print (use jax.debug.print)")
        elif func.id in {"int", "float", "bool"} and call.args \
                and _tainted_names_in(call.args[0], tainted):
            hit(f"{func.id}() on a traced value")


# ---------------------------------------------------------------------------
# R003 — structure-only runner-cache keys
# ---------------------------------------------------------------------------


def _check_cache_keys(mod: _Module, findings: List[Finding]) -> None:
    cache_vars = {
        t.id
        for node in ast.walk(mod.tree)
        if isinstance(node, (ast.Assign, ast.AnnAssign))
        for t in ([t for t in node.targets if isinstance(t, ast.Name)]
                  if isinstance(node, ast.Assign)
                  else ([node.target]
                        if isinstance(node.target, ast.Name) else []))
        if RUNNER_CACHE_NAME.match(t.id)
    }
    if not cache_vars:
        return

    def key_exprs_for(fn: ast.AST) -> List[ast.AST]:
        """Key expressions used against a runner cache inside ``fn``."""
        keys = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.value, ast.Name) \
                    and node.value.id in cache_vars:
                keys.append(node.slice)
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and isinstance(node.func.value, ast.Name) \
                    and node.func.value.id in cache_vars \
                    and node.func.attr in {"get", "setdefault", "pop"} \
                    and node.args:
                keys.append(node.args[0])
            elif isinstance(node, ast.Compare) \
                    and any(isinstance(c, ast.Name) and c.id in cache_vars
                            for c in node.comparators) \
                    and any(isinstance(op, (ast.In, ast.NotIn))
                            for op in node.ops):
                keys.append(node.left)
        return keys

    def local_assign(fn: ast.AST, name: str) -> Optional[ast.AST]:
        last = None
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and node.targets \
                    and any(isinstance(t, ast.Name) and t.id == name
                            for t in node.targets):
                last = node.value
        return last

    for fn in mod.functions:
        if isinstance(fn, ast.Lambda):
            continue
        for key in key_exprs_for(fn):
            exprs = [key]
            if isinstance(key, ast.Name):
                resolved = local_assign(fn, key.id)
                exprs = [resolved] if resolved is not None else []
            for expr in exprs:
                _audit_key_expr(mod, fn, expr, findings)


def _audit_key_expr(mod: _Module, fn: ast.AST, expr: ast.AST,
                    findings: List[Finding]) -> None:
    def local_assign(name: str) -> Optional[ast.AST]:
        last = None
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name) and t.id == name
                            for t in node.targets):
                last = node.value
        return last

    for node in ast.walk(expr):
        if isinstance(node, ast.Attribute) and node.attr in HPARAM_ATTRS:
            findings.append(Finding(
                mod.path, expr.lineno, "R003",
                f"hyperparameter '.{node.attr}' reaches a runner-cache key; "
                f"grid.py promises runner keys are structure-only "
                f"(hparams ride the traced axis)",
                mod.line_text(expr.lineno)))
        elif isinstance(node, ast.Name):
            value = local_assign(node.id)
            if isinstance(value, ast.Call) \
                    and "replace" in _names(value.func):
                zeroed = {kw.arg for kw in value.keywords
                          if kw.arg and isinstance(kw.value, ast.Constant)}
                missing = CANON_ZEROED - zeroed
                if missing:
                    findings.append(Finding(
                        mod.path, value.lineno, "R003",
                        f"replace() canonicalizing a runner-cache key "
                        f"leaves {sorted(missing)} unzeroed; cells "
                        f"differing only in hparams would stop sharing "
                        f"one compiled runner",
                        mod.line_text(value.lineno)))
            # expand local `*_key(...)` helper calls one level
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id.endswith("_key"):
            for helper in mod.defs_by_name.get(node.func.id, []):
                for sub in ast.walk(helper):
                    if isinstance(sub, ast.Attribute) \
                            and sub.attr in HPARAM_ATTRS:
                        findings.append(Finding(
                            mod.path, sub.lineno, "R003",
                            f"key helper {node.func.id}() folds "
                            f"hyperparameter '.{sub.attr}' into a "
                            f"runner-cache key",
                            mod.line_text(sub.lineno)))


# ---------------------------------------------------------------------------
# R004 — pytree registration for dataclasses crossing jit
# ---------------------------------------------------------------------------

REGISTER_CALLS = {"register_dataclass", "register_pytree_node",
                  "register_pytree_node_class", "register_static",
                  "register_pytree_with_keys", "register_pytree_with_keys_class"}


def _check_dataclass_registration(mod: _Module,
                                  findings: List[Finding]) -> None:
    registered: Set[str] = set()
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) and (_names(node.func)
                                           & REGISTER_CALLS):
            for arg in node.args:
                if isinstance(arg, ast.Name):
                    registered.add(arg.id)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ClassDef):
            continue
        dec_names = set()
        for dec in node.decorator_list:
            dec_names |= _names(dec)
        if "dataclass" not in dec_names:
            continue
        if node.name in registered or (dec_names & REGISTER_CALLS):
            continue
        array_fields = []
        for stmt in node.body:
            if isinstance(stmt, ast.AnnAssign) \
                    and isinstance(stmt.target, ast.Name):
                ann = ast.unparse(stmt.annotation)
                # Callable fields are behavior, not data — a pytree name in
                # their signature doesn't put arrays in the instance
                if PYTREE_ANN.search(ann) and "Callable" not in ann:
                    array_fields.append(stmt.target.id)
        if array_fields:
            findings.append(Finding(
                mod.path, node.lineno, "R004",
                f"dataclass {node.name} has array/pytree fields "
                f"{array_fields} but no jax.tree_util registration; it "
                f"cannot cross a jit boundary as an argument",
                mod.line_text(node.lineno)))


# ---------------------------------------------------------------------------
# R005 — donated-buffer reuse
# ---------------------------------------------------------------------------


def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """donate_argnums positions when ``call`` is a jit(...) with a constant
    donate spec, else None."""
    if "jit" not in _names(call.func):
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)) \
                and all(isinstance(e, ast.Constant)
                        and isinstance(e.value, int) for e in v.elts):
            return tuple(e.value for e in v.elts)
        return None             # conditional / computed spec: skip
    return None


def _check_donation(mod: _Module, findings: List[Finding]) -> None:
    donated_fns: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            pos = _donated_positions(node.value)
            if pos:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        donated_fns[t.id] = pos
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    pos = _donated_positions(dec)
                    if pos:
                        donated_fns[node.name] = pos
    if not donated_fns:
        return

    def scan_block(stmts: Sequence[ast.stmt]) -> None:
        stale: Dict[str, int] = {}      # name -> donation line
        for stmt in stmts:
            for name in sorted(stale):
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Name) and node.id == name \
                            and isinstance(node.ctx, ast.Load):
                        findings.append(Finding(
                            mod.path, node.lineno, "R005",
                            f"'{name}' was donated on line {stale[name]} "
                            f"(donate_argnums) and is read again; the "
                            f"buffer may already be freed",
                            mod.line_text(node.lineno)))
                        del stale[name]
                        break
            rebound = _assign_targets(stmt)
            for name in rebound:
                stale.pop(name, None)
            for node in ast.walk(stmt):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in donated_fns:
                    for pos in donated_fns[node.func.id]:
                        if pos < len(node.args) \
                                and isinstance(node.args[pos], ast.Name):
                            arg = node.args[pos].id
                            if arg not in rebound:
                                stale[arg] = node.lineno
        # end of block: stale entries die with the scope

    for fn in mod.functions:
        if not isinstance(fn, ast.Lambda):
            scan_block(fn.body)
    scan_block(mod.tree.body)


# ---------------------------------------------------------------------------
# R006 — Pallas kernel hygiene (kernels/ only)
# ---------------------------------------------------------------------------


def _check_kernel_hygiene(mod: _Module, findings: List[Finding],
                          dispatch_src: Optional[str]) -> None:
    if "kernels" not in Path(mod.path).parts:
        return
    pallas_fns = [
        fn for fn in mod.functions
        if any(isinstance(c, ast.Call) and "pallas_call" in _names(c.func)
               for c in ast.walk(fn))
    ]
    for fn in pallas_fns:
        mods_present: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mod):
                mods_present |= {n.id for n in (node.left, node.right)
                                 if isinstance(n, ast.Name)}
            elif isinstance(node, ast.Call) and "cdiv" in _names(node.func):
                mods_present |= _names(node)
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) \
                    and isinstance(node.op, ast.FloorDiv) \
                    and isinstance(node.right, ast.Name) \
                    and node.right.id not in mods_present:
                findings.append(Finding(
                    mod.path, node.lineno, "R006",
                    f"grid floordiv by '{node.right.id}' without a "
                    f"matching divisibility guard (% check, padding, or "
                    f"pl.cdiv) in the same function",
                    mod.line_text(node.lineno)))
    for kfn in mod.kernel_roots:
        params = _param_names(kfn)
        for node in ast.walk(kfn):
            if isinstance(node, (ast.If, ast.While, ast.Assert)):
                shape_on_param = any(
                    isinstance(a, ast.Attribute) and a.attr == "shape"
                    and isinstance(a.value, ast.Name) and a.value.id in params
                    for a in ast.walk(node.test))
                if shape_on_param:
                    findings.append(Finding(
                        mod.path, node.lineno, "R006",
                        "Python branching on a ref shape inside a Pallas "
                        "kernel body; block shapes are fixed by the "
                        "BlockSpec — resolve this at wrapper level",
                        mod.line_text(node.lineno)))
        has_reduction = any(
            isinstance(n, ast.Call) and (_names(n.func) & REDUCTION_CALLS)
            for n in ast.walk(kfn))
        if has_reduction:
            fp32_evidence = any(
                ("float32" in _names(n))
                or (isinstance(n, ast.keyword)
                    and n.arg == "preferred_element_type")
                for n in ast.walk(kfn))
            if not fp32_evidence:
                findings.append(Finding(
                    mod.path, kfn.lineno, "R006",
                    f"kernel '{getattr(kfn, 'name', '<lambda>')}' reduces "
                    f"without visible fp32 accumulation (.astype("
                    f"jnp.float32) or preferred_element_type); bf16 "
                    f"leaves lose precision",
                    mod.line_text(kfn.lineno)))
    stem = Path(mod.path).stem
    if pallas_fns and dispatch_src is not None \
            and stem not in {"dispatch", "__init__"} \
            and stem not in dispatch_src:
        findings.append(Finding(
            mod.path, 1, "R006",
            f"kernel module '{stem}' defines pallas_call but is not "
            f"routed through kernels/dispatch (no backend selection, "
            f"no interpret-mode fallback policy)",
            mod.line_text(1)))


# ---------------------------------------------------------------------------
# Suppressions + driver
# ---------------------------------------------------------------------------


def _suppressions(source: str) -> Dict[int, Tuple[Set[str], bool]]:
    """line -> (codes, has_justification) for `# tracelint: disable=...`."""
    out: Dict[int, Tuple[Set[str], bool]] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = SUPPRESS_RE.search(line)
        if m:
            codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
            out[i] = (codes, m.group(2) is not None)
    return out


def lint_text(source: str, path: str,
              dispatch_src: Optional[str] = None) -> List[Finding]:
    """Lint one module's source. ``path`` drives the kernels/-scoped checks;
    ``dispatch_src`` is the sibling dispatch.py source when it exists."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 1, "R000",
                        f"syntax error: {exc.msg}")]
    mod = _Module(tree, path, source)
    findings: List[Finding] = []
    _check_traced_contexts(mod, findings)
    _check_cache_keys(mod, findings)
    _check_dataclass_registration(mod, findings)
    _check_donation(mod, findings)
    _check_kernel_hygiene(mod, findings, dispatch_src)

    sup = _suppressions(source)
    kept: List[Finding] = []
    seen: Set[Tuple[int, str, str]] = set()
    for f in findings:
        codes, _ = sup.get(f.line, (set(), False))
        if f.rule in codes or "ALL" in codes:
            continue
        key = (f.line, f.rule, f.message)
        if key in seen:
            continue
        seen.add(key)
        kept.append(f)
    for line, (codes, justified) in sorted(sup.items()):
        if not justified:
            kept.append(Finding(
                path, line, "R000",
                f"suppression of {sorted(codes)} lacks a justification "
                f"(`# tracelint: disable=RXXX -- why`)",
                mod.line_text(line)))
    kept.sort(key=lambda f: (f.line, f.rule))
    return kept


def lint_file(path: Path, root: Optional[Path] = None) -> List[Finding]:
    rel = str(path.relative_to(root)) if root else str(path)
    dispatch = path.parent / "dispatch.py"
    dispatch_src = dispatch.read_text() \
        if (dispatch.exists() and path.name != "dispatch.py") else None
    return lint_text(path.read_text(), rel, dispatch_src=dispatch_src)


def iter_py_files(paths: Sequence[str]) -> Iterable[Path]:
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            yield path
        elif path.is_dir():
            for f in sorted(path.rglob("*.py")):
                if "__pycache__" not in f.parts \
                        and not any(part.startswith(".") for part in f.parts):
                    yield f


def lint_paths(paths: Sequence[str],
               root: Optional[Path] = None) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(lint_file(f, root=root))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="tracelint: trace-discipline static analysis")
    parser.add_argument("paths", nargs="*", default=["src", "benchmarks"])
    parser.add_argument("--baseline", type=Path, default=None,
                        help="grandfathered-findings file; new findings "
                             "still fail")
    parser.add_argument("--update-baseline", action="store_true",
                        help="rewrite the baseline from current findings "
                             "(keeps existing justifications)")
    parser.add_argument("--json", action="store_true", dest="as_json")
    parser.add_argument("--list-rules", action="store_true")
    args = parser.parse_args(argv)

    if args.list_rules:
        print(render_rule_table())
        return 0

    findings = lint_paths(args.paths or ["src", "benchmarks"])

    old = baseline_lib.load(args.baseline) if args.baseline else {}
    if args.update_baseline:
        if args.baseline is None:
            parser.error("--update-baseline requires --baseline")
        baseline_lib.save(args.baseline, findings, old)
        print(f"baseline written: {len(findings)} finding(s) -> "
              f"{args.baseline}")
        return 0

    new, grandfathered, stale = baseline_lib.partition(findings, old)

    if args.as_json:
        counts: Dict[str, int] = {}
        for f in new:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        print(json.dumps({
            "findings": [f.to_dict() for f in new],
            "grandfathered": len(grandfathered),
            "stale_baseline_entries": sorted(stale),
            "counts": counts,
        }, indent=2))
    else:
        for f in new:
            print(f.render())
        if grandfathered:
            print(f"[tracelint] {len(grandfathered)} grandfathered "
                  f"finding(s) suppressed by baseline", file=sys.stderr)
        for fp in sorted(stale):
            print(f"[tracelint] stale baseline entry {fp} (finding gone — "
                  f"run --update-baseline to prune)", file=sys.stderr)
        if new:
            print(f"[tracelint] {len(new)} new finding(s)", file=sys.stderr)
        else:
            print("[tracelint] clean", file=sys.stderr)
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
