"""The tracelint rule registry.

Every rule encodes one invariant the sweep stack's performance story rests
on (ROADMAP: one compiled program per (family x strategy x point x seed)
cell, zero extra jit entries).  The linter (``repro.analysis.lint``) walks
``src/repro`` and ``benchmarks`` and reports violations as ``Finding``s with
these codes; the runtime half (``repro.analysis.sanitize``) checks the same
invariants dynamically.

Suppression syntax (per line, justification required)::

    risky_call()  # tracelint: disable=R002 -- host path, runs outside jit

A ``tracelint:`` comment without the ``-- justification`` tail is itself a
finding (R000), so every grandfathered line documents *why*.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


@dataclass(frozen=True)
class Finding:
    """One linter hit: ``file:line: code message``."""

    file: str
    line: int
    rule: str
    message: str
    line_text: str = ""

    def render(self) -> str:
        return f"{self.file}:{self.line}: {self.rule} {self.message}"

    def to_dict(self) -> Dict[str, object]:
        return {"file": self.file, "line": self.line, "rule": self.rule,
                "message": self.message}


RULES: Dict[str, Rule] = {r.code: r for r in [
    Rule("R000", "suppression-hygiene",
         "a `# tracelint: disable=...` comment must carry a "
         "`-- justification` tail"),
    Rule("R001", "traced-python-branch",
         "Python if/while/assert on a value derived from a traced function's "
         "parameters (each branch value forces a retrace or a concretization "
         "error); hoist the check to build time or use lax.cond/select"),
    Rule("R002", "host-sync-in-trace",
         "host-synchronizing call (.item(), int()/float()/bool() on traced "
         "values, np.asarray, jax.device_get, block_until_ready, print) "
         "inside a scan body / round fn / jit body"),
    Rule("R003", "hparam-in-runner-cache-key",
         "swept hyperparameter (lr/gamma/alpha/sigma0/delta) reaches a "
         "runner-cache key that grid.py promises is structure-only"),
    Rule("R004", "unregistered-pytree-dataclass",
         "dataclass with array/pytree fields crosses a jit boundary without "
         "jax.tree_util registration"),
    Rule("R005", "donated-buffer-reuse",
         "argument passed to a donate_argnums position is read again after "
         "the call; the buffer may already be freed"),
    Rule("R006", "pallas-kernel-hygiene",
         "Pallas kernel hygiene: grid-divisibility guard missing, Python "
         "branching on ref shapes inside the kernel, reductions without "
         "fp32 accumulation, or a kernel module not routed through "
         "kernels/dispatch"),
]}


def render_rule_table() -> str:
    width = max(len(r.name) for r in RULES.values())
    return "\n".join(f"{r.code}  {r.name:<{width}}  {r.summary}"
                     for r in RULES.values())
