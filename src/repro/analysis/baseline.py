"""Grandfathered-findings baseline for tracelint.

The baseline lets the CI gate start green and *ratchet*: every entry pins
one existing finding by a line-content fingerprint (stable across line
drift) plus a mandatory justification, and any finding NOT in the baseline
fails the gate.  Entries whose finding disappears are reported as stale so
the file shrinks monotonically.

Fingerprint: ``sha1(file | rule | stripped-line-text | occurrence)`` — the
occurrence index disambiguates identical lines while surviving pure
re-numbering edits above them.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, List, Sequence, Set, Tuple

from repro.analysis.rules import Finding

_VERSION = 1
_DEFAULT_JUSTIFICATION = "TODO: justify or fix"


def fingerprint(finding: Finding, occurrence: int) -> str:
    raw = "|".join([finding.file, finding.rule,
                    finding.line_text.strip(), str(occurrence)])
    return hashlib.sha1(raw.encode()).hexdigest()[:16]


def attach_fingerprints(
        findings: Sequence[Finding]) -> List[Tuple[Finding, str]]:
    """Pair each finding with its fingerprint, counting duplicates of the
    same (file, rule, line text) in file order."""
    counts: Dict[Tuple[str, str, str], int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.file, f.line, f.rule)):
        key = (f.file, f.rule, f.line_text.strip())
        occ = counts.get(key, 0)
        counts[key] = occ + 1
        out.append((f, fingerprint(f, occ)))
    return out


def load(path: Path) -> Dict[str, dict]:
    """fingerprint -> entry. Every entry must carry a justification."""
    if path is None or not Path(path).exists():
        return {}
    data = json.loads(Path(path).read_text())
    entries = {}
    for e in data.get("entries", []):
        if not str(e.get("justification", "")).strip():
            raise ValueError(
                f"baseline entry {e.get('fingerprint')} "
                f"({e.get('file')}:{e.get('rule')}) has no justification; "
                f"every grandfathered finding must say why")
        entries[e["fingerprint"]] = e
    return entries


def save(path: Path, findings: Sequence[Finding],
         old: Dict[str, dict] | None = None) -> None:
    """Write the baseline for ``findings``, keeping justifications from
    ``old`` where the fingerprint survives."""
    old = old or {}
    entries = []
    for f, fp in attach_fingerprints(findings):
        entries.append({
            "fingerprint": fp,
            "file": f.file,
            "line": f.line,
            "rule": f.rule,
            "message": f.message,
            "justification": old.get(fp, {}).get(
                "justification", _DEFAULT_JUSTIFICATION),
        })
    Path(path).write_text(json.dumps(
        {"version": _VERSION, "entries": entries}, indent=2) + "\n")


def partition(findings: Sequence[Finding], baseline: Dict[str, dict],
              ) -> Tuple[List[Finding], List[Finding], Set[str]]:
    """(new, grandfathered, stale-fingerprints)."""
    new: List[Finding] = []
    grandfathered: List[Finding] = []
    seen: Set[str] = set()
    for f, fp in attach_fingerprints(findings):
        if fp in baseline:
            grandfathered.append(f)
            seen.add(fp)
        else:
            new.append(f)
    stale = set(baseline) - seen
    return new, grandfathered, stale
