"""Closed-form FedAvg bias (Proposition 1 / Eq. (3)) and helpers.

For quadratic local objectives F_i(x) = 1/2 ||x - u_i||^2 and time-invariant
Bernoulli uplinks with probabilities p_i, FedAvg's expected iterate converges
to Eq. (3):

    lim E[x^T] = sum_i  p_i u_i (1 + sum_{j=2}^m (-1)^{j+1} (1/j)
                  sum_{S subset [m]\\{i}, |S|=j-1} prod_{z in S} p_z)
                 / (1 - prod_i (1 - p_i))

(the inner sum runs over subsets of [m] \\ {i}; cf. the proof of Prop. 1 —
the theorem statement's B_j has a typo writing [m] \\ {j}).

Equivalently, the per-client weight is E[X_i / sum_j X_j | A != empty],
which we also expose via exact enumeration for validation.
"""
from __future__ import annotations

import itertools

import numpy as np


def fedavg_client_weights(p: np.ndarray) -> np.ndarray:
    """Exact E[X_i / sum X_j] / P(A != empty) by enumeration (m <= ~20)."""
    p = np.asarray(p, dtype=np.float64)
    m = len(p)
    w = np.zeros(m)
    for bits in itertools.product([0, 1], repeat=m):
        k = sum(bits)
        if k == 0:
            continue
        prob = np.prod([pi if b else 1 - pi for pi, b in zip(p, bits)])
        for i in range(m):
            if bits[i]:
                w[i] += prob / k
    return w / (1.0 - np.prod(1.0 - p))


def fedavg_fixed_point(p: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Eq. (3): limit of E[x^T] under FedAvg. u: [m, d]."""
    w = fedavg_client_weights(p)
    return (w[:, None] * np.asarray(u, dtype=np.float64)).sum(0)


def fedavg_fixed_point_series(p: np.ndarray, u: np.ndarray) -> np.ndarray:
    """Eq. (3) evaluated via the paper's inclusion-exclusion series
    (independent code path; used to cross-check `fedavg_fixed_point`)."""
    p = np.asarray(p, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    m = len(p)
    out = np.zeros(u.shape[1])
    denom = 1.0 - np.prod(1.0 - p)
    for i in range(m):
        others = [z for z in range(m) if z != i]
        inner = 1.0
        for j in range(2, m + 1):
            ssum = sum(np.prod(p[list(S)]) for S in itertools.combinations(others, j - 1))
            inner += ((-1) ** (j + 1)) * ssum / j
        out += p[i] * inner / denom * u[i]
    return out


def two_client_fixed_point(u1, u2, p1, p2):
    """Fig. 2 scalar example: closed form for m=2."""
    w1 = (p1 * (1 - p2) + p1 * p2 / 2) / (1 - (1 - p1) * (1 - p2))
    w2 = (p2 * (1 - p1) + p1 * p2 / 2) / (1 - (1 - p1) * (1 - p2))
    return w1 * u1 + w2 * u2
