"""FedPBC core: the paper's primary contribution in JAX."""
from repro.core.algorithms import ALGORITHMS, Algorithm, make_algorithm, masked_mean
from repro.core.connectivity import (
    LinkProcess,
    build_base_probs,
    make_link_process,
    p_of_t,
)
from repro.core.federated import FedState, init_fed_state, local_steps, make_round_fn

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "make_algorithm",
    "masked_mean",
    "LinkProcess",
    "build_base_probs",
    "make_link_process",
    "p_of_t",
    "FedState",
    "init_fed_state",
    "local_steps",
    "make_round_fn",
]
