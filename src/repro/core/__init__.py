"""FedPBC core: the paper's primary contribution in JAX."""
from repro.core.algorithms import ALGORITHMS, Algorithm, make_algorithm, masked_mean
from repro.core.connectivity import (
    LinkProcess,
    build_base_probs,
    make_link_process,
    p_of_t,
)
from repro.core.federated import (
    DEFAULT_METRIC_KEYS,
    FedState,
    init_fed_state,
    local_steps,
    make_round_fn,
    make_round_step,
    make_run_rounds,
    run_rounds_loop,
)

__all__ = [
    "ALGORITHMS",
    "Algorithm",
    "make_algorithm",
    "masked_mean",
    "LinkProcess",
    "build_base_probs",
    "make_link_process",
    "p_of_t",
    "DEFAULT_METRIC_KEYS",
    "FedState",
    "init_fed_state",
    "local_steps",
    "make_round_fn",
    "make_round_step",
    "make_run_rounds",
    "run_rounds_loop",
]
