"""FedPBC core: the paper's primary contribution in JAX."""
from repro.core.algorithms import (
    ALGORITHMS,
    AlgoState,
    Algorithm,
    AlgorithmSpec,
    algo_family,
    as_algorithm,
    make_algorithm,
    make_algorithm_spec,
    masked_mean,
    state_signature,
)
from repro.core.connectivity import (
    LinkProcess,
    build_base_probs,
    make_link_process,
    p_of_t,
)
from repro.core.federated import (
    DEFAULT_METRIC_KEYS,
    FedState,
    init_fed_state,
    local_steps,
    make_round_fn,
    make_round_step,
    make_run_rounds,
    run_rounds_loop,
)

__all__ = [
    "ALGORITHMS",
    "AlgoState",
    "Algorithm",
    "AlgorithmSpec",
    "algo_family",
    "as_algorithm",
    "make_algorithm",
    "make_algorithm_spec",
    "masked_mean",
    "state_signature",
    "LinkProcess",
    "build_base_probs",
    "make_link_process",
    "p_of_t",
    "DEFAULT_METRIC_KEYS",
    "FedState",
    "init_fed_state",
    "local_steps",
    "make_round_fn",
    "make_round_step",
    "make_run_rounds",
    "run_rounds_loop",
]
