"""Implicit-gossip mixing matrices (Eq. 4) and ergodicity (Lemma 3).

W^(t)_{ij} = 1/|A^t| for i,j in A^t; W_{ii} = 1 for i not in A^t; else 0.
rho = lambda_2(E[(W)^2]) < 1 whenever p_i^t >= c > 0 (Lemma 3):

    general:  rho <= 1 - c^4 (1 - (1-c)^m)^2 / 8
    uniform k-of-m: rho <= 1 - (k/m)^2 / 8
"""
from __future__ import annotations

import itertools

import numpy as np


def mixing_matrix(active: np.ndarray) -> np.ndarray:
    """Eq. (4) for one round. active: [m] bool."""
    active = np.asarray(active, dtype=bool)
    m = len(active)
    k = int(active.sum())
    W = np.zeros((m, m))
    if k <= 1:
        return np.eye(m)
    idx = np.where(active)[0]
    W[np.ix_(idx, idx)] = 1.0 / k
    for i in range(m):
        if not active[i]:
            W[i, i] = 1.0
    return W


def expected_w2(p: np.ndarray) -> np.ndarray:
    """M = E[(W)^2] by exact enumeration over active sets (m <= ~16)."""
    p = np.asarray(p, dtype=np.float64)
    m = len(p)
    M = np.zeros((m, m))
    for bits in itertools.product([0, 1], repeat=m):
        prob = np.prod([pi if b else 1 - pi for pi, b in zip(p, bits)])
        W = mixing_matrix(np.array(bits, dtype=bool))
        M += prob * (W @ W)
    return M


def expected_w2_mc(p: np.ndarray, n_samples: int, seed: int = 0) -> np.ndarray:
    """Monte-Carlo M for larger m."""
    rng = np.random.default_rng(seed)
    p = np.asarray(p)
    m = len(p)
    M = np.zeros((m, m))
    for _ in range(n_samples):
        W = mixing_matrix(rng.random(m) < p)
        M += W @ W
    return M / n_samples


def rho_of(M: np.ndarray) -> float:
    """Second-largest eigenvalue of the (symmetric, doubly-stochastic) M."""
    ev = np.sort(np.linalg.eigvalsh(M))
    return float(ev[-2])


def lemma3_general_bound(c: float, m: int) -> float:
    return 1.0 - (c ** 4) * (1.0 - (1.0 - c) ** m) ** 2 / 8.0


def lemma3_uniform_bound(k: int, m: int) -> float:
    return 1.0 - (k / m) ** 2 / 8.0


def consensus_error(clients_flat: np.ndarray) -> float:
    """(1/m) sum_i ||x_i - xbar||^2 — Eq. (5) diagnostics."""
    xbar = clients_flat.mean(0)
    return float(np.mean(np.sum((clients_flat - xbar) ** 2, axis=-1)))
