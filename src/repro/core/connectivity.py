"""Unreliable-uplink processes (paper §7.2).

Implements the paper's construction of the per-client connection
probabilities (Eq. 9) and the three unreliable schemes — Bernoulli,
two-state Markov, cyclic — each with time-invariant and time-varying /
homogeneous and non-homogeneous / reset and no-reset variants.

All processes are functional and jit-able: ``sample(state, t, key)``
returns ``(active_mask [m] bool, p_t [m], new_state)``.

The Eq.-9 dynamics knobs ``gamma`` (fluctuation amplitude) and ``period``
(sine period) default to the values baked into ``FederationConfig``, but
``make_link_process`` (and the per-scheme constructors) accept them as
explicit overrides that may be *traced* scalars: the sweep engine builds the
link process inside its compiled program from traced ``(p_base, gamma,
period)`` inputs, so a gamma ablation reuses one compile instead of baking a
new closure per value.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig


# ---------------------------------------------------------------------------
# Eq. (9): p_i construction from data heterogeneity
# ---------------------------------------------------------------------------


def build_base_probs(key, num_clients, num_classes, *, alpha=0.1, sigma0=10.0,
                     mu0=0.0, delta=0.02):
    """Paper §7.2: nu_i ~ Dirichlet(alpha); r ~ lognormal(mu0, sigma0^2)^C
    normalized; p_i = <r, nu_i> clipped at delta. Returns (p [m], nu [m, C], r [C])."""
    k1, k2 = jax.random.split(key)
    nu = jax.random.dirichlet(k1, jnp.full((num_classes,), alpha), (num_clients,))
    r = jnp.exp(mu0 + sigma0 * jax.random.normal(k2, (num_classes,)))
    r = r / r.sum()
    p = nu @ r
    return jnp.maximum(p, delta), nu, r


def p_of_t(p_base, t, *, gamma, period):
    """Eq. (9): p_i^t = p_i * [(1-gamma) + gamma * sin(2 pi t / P)].
    ``gamma``/``period`` may be python floats or traced scalars."""
    eps = jnp.sin(2.0 * jnp.pi * t / period)
    return jnp.clip(p_base * ((1.0 - gamma) + gamma * eps), 0.0, 1.0)


def _dynamics(cfg: FederationConfig, gamma, period):
    """Resolve the Eq.-9 dynamics knobs: explicit (possibly traced) overrides
    win over the config's static values."""
    return (cfg.gamma if gamma is None else gamma,
            cfg.period if period is None else period)


# ---------------------------------------------------------------------------
# Link processes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkProcess:
    init: Callable[..., Any]          # (key) -> state
    sample: Callable[..., Any]        # (state, t, key) -> (active, p_t, state)
    name: str = ""


def bernoulli_process(p_base, cfg: FederationConfig, *, gamma=None,
                      period=None) -> LinkProcess:
    tv = cfg.time_varying
    gamma, period = _dynamics(cfg, gamma, period)

    def init(key):
        return ()

    def sample(state, t, key):
        p_t = p_of_t(p_base, t, gamma=gamma, period=period) if tv else p_base
        active = jax.random.uniform(key, p_base.shape) < p_t
        return active, p_t, state

    return LinkProcess(init, sample, f"bernoulli_{'tv' if tv else 'ti'}")


def markov_process(p_base, cfg: FederationConfig, *, gamma=None,
                   period=None) -> LinkProcess:
    """Two-state ON/OFF chain, Table 3 transition construction.

    Homogeneous: transitions from time-invariant p_i.
    Non-homogeneous: transitions re-derived from time-varying p_i^t.

    Time-index convention (audited against Eq. 9 / Table 3): the mask
    returned for round ``t`` is the chain state AFTER applying the transition
    derived from ``p_of_t(t)`` — i.e. ``sample`` advances ``X_{t-1} -> X_t``
    with rates ``(q_t, q*_t) = transitions(p_i^t)`` and returns ``X_t``; the
    ``init`` draw ``X_{-1} ~ Bernoulli(p_base)`` is the pre-round seed state
    and is never itself used as a mask. The ensemble ON-fraction therefore
    follows ``mu_t = (1 - q_t - q*_t) mu_{t-1} + q*_t``: in the homogeneous
    chain ``mu_t = p_i`` exactly for every t (Table 3 rates have stationary
    distribution ``p_i`` and the init puts the chain there), while the
    non-homogeneous chain tracks ``p_i^t`` with the chain's mixing lag of
    ``O(|dp/dt| / (q + q*))`` — a real channel memory, not an indexing bug
    (``tests/test_connectivity.py`` checks both against this recursion).
    """
    tv = cfg.time_varying
    gamma, period = _dynamics(cfg, gamma, period)

    def transitions(p_t):
        p_t = jnp.clip(p_t, 1e-4, 1 - 1e-4)
        cond = 0.05 * (1.0 - p_t) <= p_t
        q_star = jnp.where(cond, 0.05, p_t / (1.0 - p_t))          # OFF -> ON
        q = jnp.where(cond, 0.05 * (1.0 - p_t) / p_t, 1.0)          # ON -> OFF
        return q, q_star

    def init(key):
        on = jax.random.uniform(key, p_base.shape) < p_base
        return on

    def sample(on, t, key):
        p_t = p_of_t(p_base, t, gamma=gamma, period=period) if tv else p_base
        q, q_star = transitions(p_t)
        u = jax.random.uniform(key, p_base.shape)
        new_on = jnp.where(on, u >= q, u < q_star)
        return new_on, p_t, new_on

    return LinkProcess(init, sample, f"markov_{'nonhom' if tv else 'hom'}")


def cyclic_process(p_base, cfg: FederationConfig, *, gamma=None,
                   period=None) -> LinkProcess:
    """Fig. 5: link active for p_i*L of every cycle of length L, after a random
    offset drawn once (no reset) or redrawn every cycle (periodic reset).

    The on/off windows are structural (driven by ``p_base`` duty cycles), but
    the reported connection probability follows bernoulli/markov semantics:
    time-varying configs report ``p_of_t`` so known-p algorithms see the same
    signal across schemes.
    """
    L = cfg.cyclic_length
    tv = cfg.time_varying
    gamma, period = _dynamics(cfg, gamma, period)

    def init(key):
        off = jax.random.uniform(key, p_base.shape) * (1.0 - p_base) * L
        return {"offset": off, "key": key}

    def sample(state, t, key):
        phase = jnp.mod(jnp.asarray(t, jnp.float32), L)
        if cfg.cyclic_reset:
            cycle = jnp.asarray(t, jnp.int32) // L
            kc = jax.random.fold_in(state["key"], cycle)
            off = jax.random.uniform(kc, p_base.shape) * (1.0 - p_base) * L
        else:
            off = state["offset"]
        active = (phase >= off) & (phase < off + p_base * L)
        p_t = p_of_t(p_base, t, gamma=gamma, period=period) if tv else p_base
        return active, p_t, state

    return LinkProcess(init, sample, f"cyclic_{'reset' if cfg.cyclic_reset else 'noreset'}")


def make_link_process(p_base, cfg: FederationConfig, *, gamma=None,
                      period=None) -> LinkProcess:
    """Build the configured scheme's process. ``gamma``/``period`` override
    the config's Eq.-9 dynamics and may be traced scalars (see module doc)."""
    kw = dict(gamma=gamma, period=period)
    if cfg.scheme == "bernoulli":
        return bernoulli_process(p_base, cfg, **kw)
    if cfg.scheme == "markov":
        return markov_process(p_base, cfg, **kw)
    if cfg.scheme == "cyclic":
        return cyclic_process(p_base, cfg, **kw)
    raise ValueError(cfg.scheme)
