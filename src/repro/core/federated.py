"""Federated round engine.

A round (Alg. 1 of the paper) is one pure, jit-able function:

    1. sample the link process -> active mask A^t;
    2. every client runs ``s`` local optimizer steps from its start params
       (vmap over the client axis — or sharded over the "pod" axis in the
       ``pod_silo`` placement);
    3. the aggregation rule updates server + client params (postponed
       broadcast for FedPBC, instant for FedAvg-style baselines).

The engine is model-agnostic: the caller provides ``loss_fn(params, batch)``
and a per-client batch pytree with a leading ``[m, ...]`` axis.

Two execution modes share the same single-round primitive:

- ``round_fn(state, batches)`` — one round per dispatch, the composable
  building block (callers feed host- or device-generated batches);
- ``run_rounds(state, ds_state, data_key, num_rounds)`` — K rounds inside ONE
  ``jax.lax.scan`` over a device-resident ``DataSource``
  (``repro.data.sources``), with donated state buffers and stacked per-round
  metrics. This removes the per-round dispatch + H2D cost that dominates
  long-horizon simulations (thousands of rounds x many link schemes).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig
from repro.core.algorithms import (
    Algorithm,
    AlgorithmSpec,
    _tile,
    as_algorithm,
    bcast_where,
    make_algorithm,
)
from repro.core.connectivity import LinkProcess
from repro.models.flags import scan_unroll

Pytree = Any


@dataclass
class FedState:
    server: Pytree
    clients: Pytree          # leading [m, ...] axis
    opt_state: Pytree        # per-client optimizer state, [m, ...]
    algo_state: Pytree
    link_state: Pytree
    round: jnp.ndarray       # scalar int32
    key: jnp.ndarray
    # staleness bookkeeping (Prop. 2): last round each uplink was active
    last_active: jnp.ndarray  # [m] int32
    # buffered semi-async aggregation (repro.scale.buffer): a BufferState
    # in buffered modes, () for the synchronous engine
    buffer: Pytree = ()


def init_fed_state(key, server_params, fed_cfg: FederationConfig,
                   algorithm, link: LinkProcess, optimizer, *,
                   stateless_clients: bool = False,
                   buffered: bool = False) -> FedState:
    """``algorithm`` may be an ``Algorithm`` or an ``AlgorithmSpec`` (whose
    unified ``init`` is dispatch-independent: every family member shares one
    state container).

    ``stateless_clients``: cohort (cross-device) mode — no ``[m, ...]``
    client params / optimizer state is materialized; every sampled client
    trains from the server model with a fresh optimizer, so per-round
    client memory is O(C). ``buffered``: thread a ``BufferState``
    (``repro.scale.buffer``) for the semi-async engine.
    """
    algorithm = as_algorithm(algorithm)
    m = fed_cfg.num_clients
    k_link, k_state = jax.random.split(key)
    if stateless_clients:
        clients, opt_state = (), ()
    else:
        clients = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (m,) + x.shape).copy(),
            server_params)
        opt_state = jax.vmap(optimizer.init)(clients)
    buffer = ()
    if buffered:
        from repro.scale.buffer import init_buffer_state
        buffer = init_buffer_state(server_params, m)
    return FedState(
        server=server_params,
        clients=clients,
        opt_state=opt_state,
        algo_state=algorithm.init(server_params, m),
        link_state=link.init(k_link),
        round=jnp.int32(0),
        key=k_state,
        last_active=jnp.full((m,), -1, jnp.int32),
        buffer=buffer,
    )


def local_steps(loss_fn, optimizer, params, opt_state, batches, s: int):
    """Run ``s`` local optimizer steps; ``batches`` has a leading [s, ...] axis
    (one mini-batch per local step). Returns (params', opt_state', mean_loss).

    Local training is deterministic given the batches: all randomness lives in
    the link process and the ``DataSource`` (stochastic local algorithms would
    take their keys via ``batches`` leaves so the scan stays key-free here).
    """

    def step(carry, batch):
        p, o = carry
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        p, o = optimizer.update(p, o, g)
        return (p, o), loss

    (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), batches,
                                               unroll=scan_unroll())
    return params, opt_state, losses.mean()


def make_round_fn(loss_fn: Callable, optimizer, algorithm,
                  link: LinkProcess, fed_cfg: FederationConfig,
                  spmd_axis_name: Optional[str] = None,
                  algo_id=0, use_kernel: bool = False,
                  strategy=None, cohort_size: Optional[int] = None,
                  gather_updates: Optional[Callable] = None):
    """Build the jit-able round function.

    ``algorithm``: an ``Algorithm``, or an ``AlgorithmSpec`` table bound at
    ``algo_id`` — which may be a *traced* scalar, in which case the round's
    client-start/aggregate lower to the family's branchless switch and one
    round function serves every member.

    ``spmd_axis_name``: mesh axis the client dimension is sharded over in the
    ``pod_silo`` placement (vmap's spmd_axis_name); None for simulated /
    stacked_data placements.

    ``use_kernel``: route a fusable family's server aggregation through the
    backend-dispatched fused Pallas kernel (``repro.kernels.dispatch``)
    instead of the XLA masked-mean switch. Ignored for an already-bound
    ``Algorithm`` (its aggregation path is baked).

    ``strategy`` / ``cohort_size``: the cross-device scale modes
    (``repro.scale``). A non-None ``strategy`` (a ``Strategy`` or a traced
    knob mapping) routes a fusable family's aggregation through the
    buffered semi-async engine; a non-None ``cohort_size`` makes the round
    subsample C clients on device (stateless clients, O(C) round memory)
    and requires a source-aware step (the returned round function carries
    ``needs_source`` and the signature
    ``round_fn(state, ds_state, k_data, source)``). Both require an
    ``AlgorithmSpec`` (the engine needs the family table, not a bound
    ``Algorithm``). None/None is the historical synchronous trace,
    untouched.

    ``gather_updates``: optional hook applied to ``(x_star, losses)`` right
    after the client vmap, before any cross-client reduction. The 2-D sweep
    path uses it to gather model-axis-sharded local updates back to
    replicated (``repro.experiments.sweep``), so every device performs the
    aggregation redundantly but identically — bit-for-bit with the
    unsharded trace. None is the identity.
    """
    if strategy is not None or cohort_size is not None:
        return _make_scale_round_fn(loss_fn, optimizer, algorithm, link,
                                    fed_cfg, spmd_axis_name, algo_id,
                                    strategy, cohort_size, gather_updates)
    algorithm = as_algorithm(algorithm, algo_id, use_kernel=use_kernel)
    s = fed_cfg.local_steps

    def round_fn(state: FedState, batches) -> tuple:
        """batches: pytree with leading [m, s, ...] (per client, per step)."""
        key, k_link = jax.random.split(state.key)
        active, p_t, link_state = link.sample(state.link_state, state.round, k_link)

        starts = algorithm.client_start(state.algo_state, state.server, state.clients)

        run = partial(local_steps, loss_fn, optimizer, s=s)
        x_star, opt_state, losses = jax.vmap(
            run, spmd_axis_name=spmd_axis_name)(
            starts, state.opt_state, batches)
        if gather_updates is not None:
            x_star, losses = gather_updates((x_star, losses))

        algo_state, server, clients = algorithm.aggregate(
            state.algo_state, state.server, state.clients, x_star, active,
            p_t, state.round)

        last_active = jnp.where(active, state.round, state.last_active)
        new_state = FedState(
            server=server, clients=clients, opt_state=opt_state,
            algo_state=algo_state, link_state=link_state,
            round=state.round + 1, key=key, last_active=last_active,
            buffer=state.buffer)
        metrics = {
            "loss": losses.mean(),
            "num_active": active.sum(),
            "active": active,
            "staleness": (state.round - state.last_active).astype(jnp.float32),
        }
        return new_state, metrics

    return round_fn


def _make_scale_round_fn(loss_fn, optimizer, algorithm, link, fed_cfg,
                         spmd_axis_name, algo_id, strategy, cohort_size,
                         gather_updates=None):
    """The cross-device scale round engines (``repro.scale``).

    Dense buffered (``cohort_size is None``): the synchronous round's exact
    data/key/mask protocol, with the server aggregation routed through the
    buffered semi-async fold — in the degenerate commit-every-round
    configuration the trace mirrors the synchronous branches term for term
    (the bit-for-bit pin in ``tests/test_staleness.py``).

    Cohort (``cohort_size=C``): clients are stateless — a ``[C]`` cohort is
    drawn per round, only its batches are sampled (``source.sample_cohort``),
    every sampled client trains from the server model with a fresh
    optimizer, and aggregation is the buffer engine (fusable family) or the
    sparse gather/scatter branches (stateful rules). No ``[m, n_params]``
    client tensor exists anywhere in the round.
    """
    from repro.scale.buffer import buffered_aggregate, knobs_of
    from repro.scale.participation import cohort_arrivals, sample_cohort

    if not isinstance(algorithm, AlgorithmSpec):
        raise ValueError(
            "the buffered/cohort round engine needs an AlgorithmSpec (got "
            f"{type(algorithm).__name__}; bind algo_id via the algo_id "
            "argument instead)")
    spec = algorithm
    m = fed_cfg.num_clients
    buffered = spec.fusable  # stateful rules take the sparse cohort path
    if strategy is not None and not buffered:
        raise ValueError(
            f"buffered strategies cover the empty-state family only; "
            f"{spec.names} keeps per-client state (use the synchronous or "
            "cohort path)")
    knobs = knobs_of(strategy)
    if buffered:
        op, is_pbc = spec.fused_op(algo_id)
    bound = as_algorithm(spec, algo_id)
    run = partial(local_steps, loss_fn, optimizer, s=fed_cfg.local_steps)

    def commit_clients(commit, in_buffer, server, x_star):
        """Postponed broadcast at commit time: fedpbc's new global model
        reaches exactly the buffered contributors; other members broadcast
        to every row present. Between commits nobody moves."""
        if isinstance(is_pbc, bool):
            bcast = in_buffer if is_pbc else jnp.ones_like(in_buffer)
        else:
            bcast = jnp.where(is_pbc, in_buffer, jnp.ones_like(in_buffer))
        committed = bcast_where(bcast, server, x_star)
        return jax.tree.map(
            lambda c, x: jnp.where(commit, c, x), committed, x_star)

    if cohort_size is None:
        def round_fn(state: FedState, batches) -> tuple:
            key, k_link = jax.random.split(state.key)
            active, p_t, link_state = link.sample(
                state.link_state, state.round, k_link)
            starts = bound.client_start(
                state.algo_state, state.server, state.clients)
            x_star, opt_state, losses = jax.vmap(
                run, spmd_axis_name=spmd_axis_name)(
                starts, state.opt_state, batches)
            if gather_updates is not None:
                x_star, losses = gather_updates((x_star, losses))
            in_buffer = state.buffer.in_buffer | active
            buf, server, commit, bmets = buffered_aggregate(
                state.buffer, state.server, x_star, active, p_t, knobs,
                op=op, m_total=m, in_buffer_new=in_buffer)
            clients = commit_clients(commit, in_buffer, server, x_star)
            last_active = jnp.where(active, state.round, state.last_active)
            new_state = FedState(
                server=server, clients=clients, opt_state=opt_state,
                algo_state=state.algo_state, link_state=link_state,
                round=state.round + 1, key=key, last_active=last_active,
                buffer=buf)
            metrics = {
                "loss": losses.mean(),
                "num_active": active.sum(),
                "active": active,
                "staleness": (state.round
                              - state.last_active).astype(jnp.float32),
                **bmets,
            }
            return new_state, metrics

        return round_fn

    C = cohort_size

    def round_fn(state: FedState, ds_state, k_data, source) -> tuple:
        key, k_link, k_cohort = jax.random.split(state.key, 3)
        # the link advances over the FULL population (Markov chains etc.
        # keep their dense-time semantics); the cohort sees its gather
        active_m, p_t_m, link_state = link.sample(
            state.link_state, state.round, k_link)
        cohort = sample_cohort(k_cohort, m, C)
        c_active, c_p = cohort_arrivals(cohort, active_m, p_t_m)
        batches, ds_state = source.sample_cohort(
            ds_state, state.round, k_data, cohort)
        starts = _tile(state.server, C)
        opt_state = jax.vmap(optimizer.init)(starts)
        x_star, _, losses = jax.vmap(run, spmd_axis_name=spmd_axis_name)(
            starts, opt_state, batches)
        if gather_updates is not None:
            x_star, losses = gather_updates((x_star, losses))
        if buffered:
            in_buffer = state.buffer.in_buffer.at[cohort].set(
                state.buffer.in_buffer[cohort] | c_active)
            buf, server, commit, bmets = buffered_aggregate(
                state.buffer, state.server, x_star, c_active, c_p, knobs,
                op=op, m_total=C, in_buffer_new=in_buffer)
            algo_state = state.algo_state
        else:
            algo_state, server = spec.aggregate_cohort(
                algo_id, state.algo_state, state.server, x_star, cohort,
                c_active, c_p, state.round)
            buf = state.buffer
            bmets = {"commit": jnp.float32(1.0),
                     "buffer_fill": c_active.sum().astype(jnp.float32),
                     "commit_staleness": jnp.float32(0.0)}
        last_active = state.last_active.at[cohort].set(
            jnp.where(c_active, state.round, state.last_active[cohort]))
        new_state = FedState(
            server=server, clients=(), opt_state=(),
            algo_state=algo_state, link_state=link_state,
            round=state.round + 1, key=key, last_active=last_active,
            buffer=buf)
        metrics = {
            "loss": losses.mean(),
            "num_active": c_active.sum(),
            "active": c_active,
            "staleness": (state.round
                          - state.last_active).astype(jnp.float32),
            **bmets,
        }
        return new_state, ds_state, metrics

    round_fn.needs_source = True
    return round_fn


# ---------------------------------------------------------------------------
# Multi-round scan engine
# ---------------------------------------------------------------------------

# Metrics stacked per round by run_rounds. "active" ([K, m] bool) is cheap but
# redundant with staleness for most consumers; callers opt in via metric_keys.
DEFAULT_METRIC_KEYS = ("loss", "num_active", "staleness")


def make_round_step(round_fn, source):
    """One (sample batch -> run round) step over a ``DataSource``.

    The per-round data key is ``fold_in(data_key, state.round)`` — a pure
    function of the carried round counter — so the scanned engine and a
    sequential Python loop over this very function draw identical batches.
    Returns ``step(state, ds_state, data_key) -> (state, ds_state, metrics)``.
    """

    if getattr(round_fn, "needs_source", False):
        # cohort engine: the round draws its own cohort and samples only
        # that cohort's batches, so it needs the source inside; the source
        # capability check belongs here, at build time, not in the traced
        # round body
        if source.sample_cohort is None:
            raise ValueError(
                f"cohort mode needs a DataSource with sample_cohort "
                f"(source {source.name!r} has none)")

        def step(state: FedState, ds_state, data_key):
            k_data = jax.random.fold_in(data_key, state.round)
            return round_fn(state, ds_state, k_data, source)

        return step

    def step(state: FedState, ds_state, data_key):
        k_data = jax.random.fold_in(data_key, state.round)
        batches, ds_state = source.sample(ds_state, state.round, k_data)
        state, metrics = round_fn(state, batches)
        return state, ds_state, metrics

    return step


def make_run_rounds(loss_fn: Callable, optimizer, algorithm,
                    link: LinkProcess, fed_cfg: FederationConfig, source,
                    spmd_axis_name: Optional[str] = None,
                    metric_keys=DEFAULT_METRIC_KEYS,
                    donate: Optional[bool] = None,
                    algo_id=0, use_kernel: bool = False,
                    strategy=None, cohort_size: Optional[int] = None):
    """Build the scanned multi-round entry point.

    ``algorithm`` may be an ``AlgorithmSpec`` table bound at ``algo_id``
    with the aggregation path picked by ``use_kernel`` (see
    ``make_round_fn``). ``strategy``/``cohort_size`` select the
    cross-device scale engines (``repro.scale``; see ``make_round_fn``) —
    in those modes the state must come from ``init_fed_state`` with the
    matching ``buffered``/``stateless_clients`` flags.

    Returns ``run_rounds(state, ds_state, data_key, num_rounds)`` →
    ``(state', ds_state', metrics)`` where every entry of ``metrics`` is a
    device array with a leading ``[num_rounds]`` axis (e.g. ``loss [K]``,
    ``staleness [K, m]``). ``num_rounds`` is static (one compile per distinct
    chunk length); ``state``/``ds_state`` buffers are donated on backends that
    support donation, so chunked callers can loop
    ``state, ds_state, mets = run_rounds(state, ds_state, key, chunk)``
    without doubling peak memory.
    """
    round_fn = make_round_fn(loss_fn, optimizer, algorithm, link, fed_cfg,
                             spmd_axis_name, algo_id=algo_id,
                             use_kernel=use_kernel, strategy=strategy,
                             cohort_size=cohort_size)
    step = make_round_step(round_fn, source)
    if donate is None:
        donate = jax.default_backend() != "cpu"  # CPU ignores donation noisily

    def run_rounds(state: FedState, ds_state, data_key, num_rounds: int):
        def body(carry, _):
            st, ds = carry
            st, ds, metrics = step(st, ds, data_key)
            return (st, ds), {k: metrics[k] for k in metric_keys}

        # unroll=1 always: num_rounds can be in the thousands, and the
        # analysis-mode full unroll (repro.models.flags) is for layer stacks,
        # not the round loop.
        (state, ds_state), metrics = jax.lax.scan(
            body, (state, ds_state), None, length=num_rounds)
        return state, ds_state, metrics

    return jax.jit(run_rounds, static_argnums=(3,),
                   donate_argnums=(0, 1) if donate else ())


def run_rounds_loop(state: FedState, ds_state, data_key, num_rounds: int, *,
                    round_fn, source, metric_keys=DEFAULT_METRIC_KEYS,
                    step=None):
    """Sequential reference: the SAME step as the scanned engine, dispatched
    once per round from Python. Used by the equivalence tests and as the
    baseline of ``benchmarks/throughput.py``; prefer ``make_run_rounds`` for
    real work.

    ``step``: pass a prebuilt ``jax.jit(make_round_step(round_fn, source))``
    to reuse its compile cache across calls (each default-built closure gets
    its own cache entry)."""
    if step is None:
        step = jax.jit(make_round_step(round_fn, source))
    collected = []
    for _ in range(num_rounds):
        state, ds_state, metrics = step(state, ds_state, data_key)
        collected.append({k: metrics[k] for k in metric_keys})
    if collected:
        stacked = {k: jnp.stack([m[k] for m in collected]) for k in metric_keys}
    else:
        # match the scanned engine: a [0, ...] leading axis on every metric's
        # true per-round shape (e.g. staleness [0, m]), not a bare [0]
        shapes = jax.eval_shape(step, state, ds_state, data_key)[2]
        stacked = {k: jnp.zeros((0,) + shapes[k].shape, shapes[k].dtype)
                   for k in metric_keys}
    return state, ds_state, stacked


jax.tree_util.register_dataclass(
    FedState,
    data_fields=["server", "clients", "opt_state", "algo_state", "link_state",
                 "round", "key", "last_active", "buffer"],
    meta_fields=[],
)
