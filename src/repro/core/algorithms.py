"""Federated aggregation algorithms over arbitrary parameter pytrees.

Every algorithm is expressed through two pure functions acting on a
``FedState`` whose client-indexed leaves carry a leading ``[m, ...]`` axis:

- ``client_start(algo_state, server, clients) -> [m, ...] start params``
  (what each client trains from this round);
- ``aggregate(algo_state, server, clients, x_star, active, p_t, t)
  -> (algo_state, server', clients')`` (server update + postponed/instant
  broadcast semantics).

FedPBC (the paper, Alg. 1): clients always start from their *own* model
(implicit gossiping); the server averages the active clients' models and
broadcasts the average back **only to the active clients** — the postponed
broadcast. The resulting mixing matrix is Eq. (4).

Baselines: FedAvg, FedAvg-all, FedAU, MIFA, FedAvg-known-p, F3AST
(§7.2, "Baseline algorithms").
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import FederationConfig

Pytree = Any


def _bmask(mask, leaf):
    """Broadcast [m] mask against an [m, ...] leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def masked_mean(xs: Pytree, active) -> Pytree:
    """Mean over the client axis restricted to active clients.

    = (1/|A|) sum_{i in A} x_i ; falls back to 0 when A is empty (callers
    guard with ``any_active``). This is the paper's server aggregation and
    the op kernelized in ``repro.kernels.masked_agg``.
    """
    denom = jnp.maximum(active.sum().astype(jnp.float32), 1.0)
    return jax.tree.map(
        lambda x: (x * _bmask(active, x)).sum(0) / denom.astype(x.dtype), xs)


def weighted_sum(xs: Pytree, w) -> Pytree:
    return jax.tree.map(lambda x: (x * _bmask(w, x)).sum(0), xs)


def bcast_where(active, new: Pytree, old: Pytree) -> Pytree:
    """Per-client select: active clients receive ``new``, others keep ``old``."""
    return jax.tree.map(
        lambda n, o: jnp.where(_bmask(active, o) > 0, jnp.broadcast_to(n, o.shape), o),
        new, old)


@dataclass(frozen=True)
class Algorithm:
    name: str
    init: Callable[[Pytree, int], Pytree]
    client_start: Callable[..., Pytree]
    aggregate: Callable[..., tuple]
    needs_p: bool = False


def _tile(server: Pytree, m: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape).copy(), server)


# ---------------------------------------------------------------------------
# FedPBC — the paper's algorithm
# ---------------------------------------------------------------------------


def fedpbc() -> Algorithm:
    def init(server, m):
        return ()

    def client_start(algo, server, clients):
        return clients  # each client resumes from its own (possibly stale) model

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        any_active = active.any()
        agg = masked_mean(x_star, active)
        new_server = jax.tree.map(
            lambda a, s: jnp.where(any_active, a, s), agg, server)
        # postponed broadcast: only active clients receive the new global model
        new_clients = bcast_where(active, new_server, x_star)
        return algo, new_server, new_clients

    return Algorithm("fedpbc", init, client_start, aggregate)


# ---------------------------------------------------------------------------
# FedAvg family
# ---------------------------------------------------------------------------


def fedavg() -> Algorithm:
    """Vanilla FedAvg: broadcast at round start; average active clients."""

    def init(server, m):
        return ()

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        any_active = active.any()
        agg = masked_mean(x_star, active)
        new_server = jax.tree.map(lambda a, s: jnp.where(any_active, a, s), agg, server)
        m = active.shape[0]
        return algo, new_server, _tile(new_server, m)

    return Algorithm("fedavg", init, client_start, aggregate)


def fedavg_all() -> Algorithm:
    """FedAvg-all: average over ALL m clients; inactive contribute zero update."""

    def init(server, m):
        return ()

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        w = active.astype(jnp.float32) / m
        delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
        upd = weighted_sum(delta, w)
        new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
        return algo, new_server, _tile(new_server, m)

    return Algorithm("fedavg_all", init, client_start, aggregate)


def fedavg_known_p() -> Algorithm:
    """FedAvg with known p_i^t: active updates importance-weighted by 1/p_i^t."""

    def init(server, m):
        return ()

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        w = active.astype(jnp.float32) / jnp.maximum(p_t, 1e-3) / m
        delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
        upd = weighted_sum(delta, w)
        new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
        return algo, new_server, _tile(new_server, m)

    return Algorithm("fedavg_known_p", init, client_start, aggregate, needs_p=True)


# ---------------------------------------------------------------------------
# FedAU (Wang & Ji 2023): online estimate of participation via mean
# inter-participation gap, capped at K.
# ---------------------------------------------------------------------------


def fedau(K: int = 50) -> Algorithm:
    def init(server, m):
        return {
            "gap": jnp.zeros((m,), jnp.float32),       # rounds since last active
            "sum_gaps": jnp.zeros((m,), jnp.float32),
            "n_gaps": jnp.zeros((m,), jnp.float32),
        }

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        gap = jnp.minimum(algo["gap"] + 1.0, float(K))
        sum_gaps = algo["sum_gaps"] + jnp.where(active, gap, 0.0)
        n_gaps = algo["n_gaps"] + active.astype(jnp.float32)
        mean_gap = jnp.where(n_gaps > 0, sum_gaps / jnp.maximum(n_gaps, 1.0), 1.0)
        w = active.astype(jnp.float32) * mean_gap / m   # mean gap ~= 1/p_i
        delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
        upd = weighted_sum(delta, w)
        new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
        new_algo = {
            "gap": jnp.where(active, 0.0, gap),
            "sum_gaps": sum_gaps,
            "n_gaps": n_gaps,
        }
        return new_algo, new_server, _tile(new_server, m)

    return Algorithm("fedau", init, client_start, aggregate)


# ---------------------------------------------------------------------------
# MIFA (Gu et al. 2021): memory of every client's last normalized update.
# ---------------------------------------------------------------------------


def mifa() -> Algorithm:
    def init(server, m):
        return {"mem": _tile(jax.tree.map(jnp.zeros_like, server), m)}

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
        mem = jax.tree.map(
            lambda old, new: jnp.where(_bmask(active, old) > 0, new.astype(old.dtype), old),
            algo["mem"], delta)
        upd = jax.tree.map(lambda g: g.mean(0), mem)
        new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
        return {"mem": mem}, new_server, _tile(new_server, m)

    return Algorithm("mifa", init, client_start, aggregate)


# ---------------------------------------------------------------------------
# F3AST (Ribero et al. 2022): availability-balanced scheduling — the server
# selects at most `cap` active clients, preferring those with the SMALLEST
# long-run availability estimate lambda_i; lambda tracked by EMA.
# ---------------------------------------------------------------------------


def f3ast(beta: float = 0.01, cap: int = 10) -> Algorithm:
    def init(server, m):
        return {"lam": jnp.full((m,), 0.5, jnp.float32)}

    def client_start(algo, server, clients):
        m = jax.tree.leaves(clients)[0].shape[0]
        return _tile(server, m)

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        lam = (1.0 - beta) * algo["lam"] + beta * active.astype(jnp.float32)
        # rank active clients by lambda ascending; keep `cap`
        score = jnp.where(active, lam, jnp.inf)
        order = jnp.argsort(score)
        rank = jnp.argsort(order)
        selected = active & (rank < cap)
        any_sel = selected.any()
        agg = masked_mean(x_star, selected)
        new_server = jax.tree.map(lambda a, s: jnp.where(any_sel, a, s), agg, server)
        return {"lam": lam}, new_server, _tile(new_server, m)

    return Algorithm("f3ast", init, client_start, aggregate)


# ---------------------------------------------------------------------------
# FedPBC-M (beyond-paper): FedPBC + server momentum on the aggregated
# direction. The postponed-broadcast/gossip structure is unchanged (the
# momentum acts on x^{t+1} - x^t, which Thm. 1's descent lemma controls);
# empirically it accelerates the information-mixing phase under sparse
# participation. Recorded as an EXTENSION, not part of the reproduction.
# ---------------------------------------------------------------------------


def fedpbc_m(beta: float = 0.8) -> Algorithm:
    def init(server, m):
        return {"mom": jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), server)}

    def client_start(algo, server, clients):
        return clients

    def aggregate(algo, server, clients, x_star, active, p_t, t):
        any_active = active.any()
        agg = masked_mean(x_star, active)
        step = jax.tree.map(
            lambda a, s: jnp.where(any_active, a.astype(jnp.float32)
                                   - s.astype(jnp.float32), 0.0), agg, server)
        mom = jax.tree.map(lambda m_, g: beta * m_ + g, algo["mom"], step)
        new_server = jax.tree.map(
            lambda s, m_: (s.astype(jnp.float32) + m_).astype(s.dtype), server, mom)
        new_clients = bcast_where(active, new_server, x_star)
        return {"mom": mom}, new_server, new_clients

    return Algorithm("fedpbc_m", init, client_start, aggregate)


ALGORITHMS = {
    "fedpbc": fedpbc,
    "fedpbc_m": fedpbc_m,
    "fedavg": fedavg,
    "fedavg_all": fedavg_all,
    "fedau": fedau,
    "mifa": mifa,
    "fedavg_known_p": fedavg_known_p,
    "f3ast": f3ast,
}


def make_algorithm(cfg: FederationConfig) -> Algorithm:
    name = cfg.algorithm
    if name == "fedau":
        return fedau(cfg.fedau_K)
    if name == "f3ast":
        return f3ast(cfg.f3ast_beta, cfg.f3ast_cap)
    if name == "fedpbc_m":
        return fedpbc_m()
    return ALGORITHMS[name]()
