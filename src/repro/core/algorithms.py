"""Federated aggregation algorithms over arbitrary parameter pytrees.

The algorithm layer is **data, not closures**: every aggregation rule is one
entry of a per-family table inside an :class:`AlgorithmSpec`, and behavior is
selected by an ``algo_id`` that may be a *traced* per-trajectory input. Two
pure functions act on a ``FedState`` whose client-indexed leaves carry a
leading ``[m, ...]`` axis:

- ``client_start(algo_id, algo_state, server, clients) -> [m, ...] start
  params`` (what each client trains from this round) — a branchless select
  between "resume from your own model" (FedPBC's implicit gossiping) and
  "broadcast the server model";
- ``aggregate(algo_id, algo_state, server, clients, x_star, active, p_t, t)
  -> (algo_state, server', clients')`` — a ``lax.switch`` over the family's
  branch table (server update + postponed/instant broadcast semantics).

All per-algorithm state lives in ONE superset container, :class:`AlgoState`:
FedAU's inter-participation gap stats, MIFA's per-client update memory,
F3AST's availability rates, FedPBC-M's server momentum. Leaves a family never
uses are **zero-sized** (leading axis 0 — no storage, stable pytree
structure); leaves only *some* members use are materialized for the whole
family and simply passed through untouched by the others (masked). Because
the state is a plain pytree selected by data, a whole state-compatible family
(e.g. fedavg / fedavg_all / fedavg_known_p / fedpbc, all with empty state)
runs as ONE compiled program over a batched ``algo_id`` — the sweep engine's
algorithm axis (``repro.experiments``).

FedPBC (the paper, Alg. 1): clients always start from their *own* model
(implicit gossiping); the server averages the active clients' models and
broadcasts the average back **only to the active clients** — the postponed
broadcast. The resulting mixing matrix is Eq. (4).

Baselines: FedAvg, FedAvg-all, FedAU, MIFA, FedAvg-known-p, F3AST
(§7.2, "Baseline algorithms").

The legacy single-algorithm :class:`Algorithm` interface (``make_algorithm``,
the per-name factories) is preserved: it binds a one-member spec with a
*static* ``algo_id``, which dispatches directly to the branch — the same
trace as the historical closures, so existing callers and their bit-for-bit
guarantees are untouched.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, FrozenSet, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederationConfig

Pytree = Any


def _bmask(mask, leaf):
    """Broadcast [m] mask against an [m, ...] leaf."""
    return mask.reshape(mask.shape + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)


def masked_mean(xs: Pytree, active) -> Pytree:
    """Mean over the client axis restricted to active clients.

    = (1/|A|) sum_{i in A} x_i ; falls back to 0 when A is empty (callers
    guard with ``any_active``). This is the paper's server aggregation and
    the op kernelized in ``repro.kernels.masked_agg``.
    """
    denom = jnp.maximum(active.sum().astype(jnp.float32), 1.0)
    return jax.tree.map(
        lambda x: (x * _bmask(active, x)).sum(0) / denom.astype(x.dtype), xs)


def weighted_sum(xs: Pytree, w) -> Pytree:
    return jax.tree.map(lambda x: (x * _bmask(w, x)).sum(0), xs)


def bcast_where(active, new: Pytree, old: Pytree) -> Pytree:
    """Per-client select: active clients receive ``new``, others keep ``old``."""
    return jax.tree.map(
        lambda n, o: jnp.where(_bmask(active, o) > 0, jnp.broadcast_to(n, o.shape), o),
        new, old)


@dataclass(frozen=True)
class Algorithm:
    """A spec bound to one (static or traced) ``algo_id`` — the historical
    single-algorithm interface every sequential caller uses."""

    name: str
    init: Callable[[Pytree, int], Pytree]
    client_start: Callable[..., Pytree]
    aggregate: Callable[..., tuple]
    needs_p: bool = False


def _tile(server: Pytree, m: int) -> Pytree:
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (m,) + x.shape).copy(), server)


# ---------------------------------------------------------------------------
# The unified algorithm state: one superset container for every rule's needs.
# ---------------------------------------------------------------------------


@dataclass
class AlgoState:
    """Superset per-algorithm state. Fields a family does not need are
    zero-sized (leading axis 0); fields only some members need are full-sized
    and inert for the others. ``mem``/``mom`` mirror the server params pytree
    with a leading client (m) / singleton (1) axis respectively."""

    gap: Pytree        # [m] rounds since last active (FedAU), or [0]
    sum_gaps: Pytree   # [m] accumulated gaps (FedAU), or [0]
    n_gaps: Pytree     # [m] gap counts (FedAU), or [0]
    lam: Pytree        # [m] availability EMA (F3AST), or [0]
    mem: Pytree        # [m, ...] last normalized updates (MIFA), or [0, ...]
    mom: Pytree        # [1, ...] server momentum (FedPBC-M), or [0, ...]


jax.tree_util.register_dataclass(
    AlgoState,
    data_fields=["gap", "sum_gaps", "n_gaps", "lam", "mem", "mom"],
    meta_fields=[],
)


# ---------------------------------------------------------------------------
# Branch table: one aggregate function per rule, all over the unified state.
# Each branch must preserve the state's structure/shapes (lax.switch needs
# identical output signatures across a family) — untouched fields pass
# through bitwise.
# ---------------------------------------------------------------------------


def _agg_fedpbc(algo, server, clients, x_star, active, p_t, t):
    """FedPBC (Alg. 1): masked mean over active clients; postponed broadcast."""
    any_active = active.any()
    agg = masked_mean(x_star, active)
    new_server = jax.tree.map(
        lambda a, s: jnp.where(any_active, a, s), agg, server)
    # postponed broadcast: only active clients receive the new global model
    new_clients = bcast_where(active, new_server, x_star)
    return algo, new_server, new_clients


def _agg_fedavg(algo, server, clients, x_star, active, p_t, t):
    """Vanilla FedAvg: average active clients; broadcast to everyone."""
    any_active = active.any()
    agg = masked_mean(x_star, active)
    new_server = jax.tree.map(lambda a, s: jnp.where(any_active, a, s), agg, server)
    m = active.shape[0]
    return algo, new_server, _tile(new_server, m)


def _agg_fedavg_all(algo, server, clients, x_star, active, p_t, t):
    """FedAvg-all: average over ALL m clients; inactive contribute zero."""
    m = active.shape[0]
    w = active.astype(jnp.float32) / m
    delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
    upd = weighted_sum(delta, w)
    new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
    return algo, new_server, _tile(new_server, m)


def _agg_fedavg_known_p(algo, server, clients, x_star, active, p_t, t):
    """FedAvg with known p_i^t: active updates importance-weighted by 1/p_i^t."""
    m = active.shape[0]
    w = active.astype(jnp.float32) / jnp.maximum(p_t, 1e-3) / m
    delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
    upd = weighted_sum(delta, w)
    new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
    return algo, new_server, _tile(new_server, m)


def _make_agg_fedau(K: int):
    """FedAU (Wang & Ji 2023): online participation estimate via mean
    inter-participation gap, capped at K."""

    def branch(algo, server, clients, x_star, active, p_t, t):
        m = active.shape[0]
        gap = jnp.minimum(algo.gap + 1.0, float(K))
        sum_gaps = algo.sum_gaps + jnp.where(active, gap, 0.0)
        n_gaps = algo.n_gaps + active.astype(jnp.float32)
        mean_gap = jnp.where(n_gaps > 0, sum_gaps / jnp.maximum(n_gaps, 1.0), 1.0)
        w = active.astype(jnp.float32) * mean_gap / m   # mean gap ~= 1/p_i
        delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
        upd = weighted_sum(delta, w)
        new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
        new_algo = dataclasses.replace(
            algo, gap=jnp.where(active, 0.0, gap), sum_gaps=sum_gaps,
            n_gaps=n_gaps)
        return new_algo, new_server, _tile(new_server, m)

    return branch


def _agg_mifa(algo, server, clients, x_star, active, p_t, t):
    """MIFA (Gu et al. 2021): memory of every client's last normalized update."""
    m = active.shape[0]
    delta = jax.tree.map(lambda xs, s: xs.astype(jnp.float32) - s[None].astype(jnp.float32), x_star, server)
    mem = jax.tree.map(
        lambda old, new: jnp.where(_bmask(active, old) > 0, new.astype(old.dtype), old),
        algo.mem, delta)
    upd = jax.tree.map(lambda g: g.mean(0), mem)
    new_server = jax.tree.map(lambda s, u: s + u.astype(s.dtype), server, upd)
    return dataclasses.replace(algo, mem=mem), new_server, _tile(new_server, m)


def _make_agg_f3ast(beta: float, cap: int):
    """F3AST (Ribero et al. 2022): availability-balanced scheduling — keep at
    most ``cap`` active clients with the SMALLEST availability EMA lambda_i."""

    def branch(algo, server, clients, x_star, active, p_t, t):
        lam = (1.0 - beta) * algo.lam + beta * active.astype(jnp.float32)
        # rank active clients by lambda ascending; keep `cap`
        score = jnp.where(active, lam, jnp.inf)
        order = jnp.argsort(score)
        rank = jnp.argsort(order)
        selected = active & (rank < cap)
        any_sel = selected.any()
        agg = masked_mean(x_star, selected)
        new_server = jax.tree.map(lambda a, s: jnp.where(any_sel, a, s), agg, server)
        m = active.shape[0]
        return dataclasses.replace(algo, lam=lam), new_server, _tile(new_server, m)

    return branch


def _make_agg_fedpbc_m(beta: float):
    """FedPBC-M (beyond-paper): FedPBC + server momentum on the aggregated
    direction. The postponed-broadcast/gossip structure is unchanged (the
    momentum acts on x^{t+1} - x^t, which Thm. 1's descent lemma controls);
    empirically it accelerates the information-mixing phase under sparse
    participation. Recorded as an EXTENSION, not part of the reproduction."""

    def branch(algo, server, clients, x_star, active, p_t, t):
        any_active = active.any()
        agg = masked_mean(x_star, active)
        step = jax.tree.map(
            lambda a, s: jnp.where(any_active, a.astype(jnp.float32)
                                   - s.astype(jnp.float32), 0.0), agg, server)
        mom = jax.tree.map(lambda m_, g: beta * m_[0] + g, algo.mom, step)
        new_server = jax.tree.map(
            lambda s, m_: (s.astype(jnp.float32) + m_).astype(s.dtype), server, mom)
        new_clients = bcast_where(active, new_server, x_star)
        new_algo = dataclasses.replace(
            algo, mom=jax.tree.map(lambda x: x[None], mom))
        return new_algo, new_server, new_clients

    return branch


@dataclass(frozen=True)
class _AlgoDef:
    """Registry row: which AlgoState fields a rule materializes, where its
    clients start from, whether it consumes p_i^t, and its branch factory
    (knobs -> aggregate function)."""

    needs: FrozenSet[str]
    from_clients: bool
    needs_p: bool
    make_branch: Callable[["AlgorithmSpec"], Callable]


_DEFS: Dict[str, _AlgoDef] = {
    "fedpbc": _AlgoDef(frozenset(), True, False, lambda spec: _agg_fedpbc),
    "fedpbc_m": _AlgoDef(frozenset({"mom"}), True, False,
                         lambda spec: _make_agg_fedpbc_m(spec.fedpbc_m_beta)),
    "fedavg": _AlgoDef(frozenset(), False, False, lambda spec: _agg_fedavg),
    "fedavg_all": _AlgoDef(frozenset(), False, False,
                           lambda spec: _agg_fedavg_all),
    "fedau": _AlgoDef(frozenset({"gap", "sum_gaps", "n_gaps"}), False, False,
                      lambda spec: _make_agg_fedau(spec.fedau_K)),
    "mifa": _AlgoDef(frozenset({"mem"}), False, False, lambda spec: _agg_mifa),
    "fedavg_known_p": _AlgoDef(frozenset(), False, True,
                               lambda spec: _agg_fedavg_known_p),
    "f3ast": _AlgoDef(frozenset({"lam"}), False, False,
                      lambda spec: _make_agg_f3ast(spec.f3ast_beta,
                                                   spec.f3ast_cap)),
}


def state_signature(name: str) -> FrozenSet[str]:
    """The AlgoState fields ``name`` materializes — its batching-compatibility
    class. Algorithms with equal signatures share state shapes and batch into
    one compiled program."""
    if name not in _DEFS:
        raise ValueError(
            f"unknown algorithm {name!r}; available: {sorted(_DEFS)}")
    return _DEFS[name].needs


def algo_family(name: str) -> Tuple[str, ...]:
    """The canonical state-compatible family containing ``name``: every
    registered algorithm with the same state signature, in registry order.
    ``algo_id`` values index this tuple, and the executor keys its runner
    cache on it — so any subset of a family shares one compiled program."""
    sig = state_signature(name)
    return tuple(n for n in _DEFS if _DEFS[n].needs == sig)


def _is_static(algo_id) -> bool:
    return isinstance(algo_id, (int, np.integer))


@dataclass(frozen=True)
class AlgorithmSpec:
    """A family of aggregation rules as data: member ``names`` (indexed by
    ``algo_id``) plus their static knobs. ``client_start``/``aggregate`` are
    implemented ONCE over the branch table — with a static (python int)
    ``algo_id`` they dispatch directly (the historical per-algorithm trace);
    with a traced ``algo_id`` they lower to a branchless select /
    ``lax.switch``, which under ``vmap`` evaluates every branch and selects
    per trajectory, so one program serves the whole family."""

    names: Tuple[str, ...]
    fedau_K: int = 50
    f3ast_beta: float = 0.01
    f3ast_cap: int = 10
    fedpbc_m_beta: float = 0.8

    def __post_init__(self):
        if not self.names:
            raise ValueError("AlgorithmSpec.names must be non-empty")
        unknown = [n for n in self.names if n not in _DEFS]
        if unknown:
            raise ValueError(
                f"AlgorithmSpec.names contains unknown algorithms {unknown}; "
                f"available: {sorted(_DEFS)}")
        if len(set(self.names)) != len(self.names):
            raise ValueError(
                f"AlgorithmSpec.names contains duplicates: {self.names}")

    @property
    def needs(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for n in self.names:
            out = out | _DEFS[n].needs
        return out

    @property
    def needs_p(self) -> bool:
        return any(_DEFS[n].needs_p for n in self.names)

    def id_of(self, name: str) -> int:
        """Index of ``name`` in this spec's table (the value an ``algo_id``
        input must carry to select it)."""
        if name not in self.names:
            raise ValueError(f"{name!r} is not in this spec's family "
                             f"{self.names}")
        return self.names.index(name)

    # -- the two per-round primitives, implemented once over the table -----

    def init(self, server: Pytree, m: int) -> AlgoState:
        """The family's unified state: needed fields at full size, the rest
        zero-sized (leading axis 0)."""
        u = self.needs

        def vec(field, fill=0.0):
            n = m if field in u else 0
            return jnp.full((n,), fill, jnp.float32)

        mem_m = m if "mem" in u else 0
        mom_m = 1 if "mom" in u else 0
        return AlgoState(
            gap=vec("gap"), sum_gaps=vec("sum_gaps"), n_gaps=vec("n_gaps"),
            lam=vec("lam", 0.5),
            mem=jax.tree.map(
                lambda x: jnp.zeros((mem_m,) + x.shape, x.dtype), server),
            mom=jax.tree.map(
                lambda x: jnp.zeros((mom_m,) + x.shape, jnp.float32), server),
        )

    def client_start(self, algo_id, algo_state, server: Pytree,
                     clients: Pytree) -> Pytree:
        m = jax.tree.leaves(clients)[0].shape[0]
        if _is_static(algo_id) or len(self.names) == 1:
            idx = int(algo_id) if _is_static(algo_id) else 0
            return clients if _DEFS[self.names[idx]].from_clients \
                else _tile(server, m)
        from_clients = jnp.asarray(
            [_DEFS[n].from_clients for n in self.names])[algo_id]
        tiled = _tile(server, m)
        return jax.tree.map(
            lambda c, s: jnp.where(from_clients, c, s), clients, tiled)

    @property
    def fusable(self) -> bool:
        """Whether every member's aggregation folds into the fused Pallas
        kernel's branch select (``repro.kernels.dispatch.FUSED_OPS``) — the
        empty-state family. Stateful rules keep the ``lax.switch`` path."""
        from repro.kernels.dispatch import FUSED_OPS
        return all(n in FUSED_OPS for n in self.names)

    def fused_op(self, algo_id) -> tuple:
        """The fused-branch selectors for a fusable family: ``(op, is_pbc)``
        where ``op`` is the member's aggregation opcode
        (``repro.kernels.dispatch.FUSED_OPS``) and ``is_pbc`` marks the
        postponed-broadcast member. Python scalars for a static ``algo_id``,
        traced selects otherwise — the shared dispatch of the fused kernel
        path and the buffered engine (``repro.scale.buffer``)."""
        from repro.kernels.dispatch import FUSED_OPS

        if _is_static(algo_id):
            name = self.names[int(algo_id)]
            return FUSED_OPS[name], name == "fedpbc"
        op = jnp.asarray([FUSED_OPS[n] for n in self.names],
                         jnp.int32)[algo_id]
        is_pbc = jnp.asarray([n == "fedpbc" for n in self.names])[algo_id]
        return op, is_pbc

    def aggregate_cohort(self, algo_id, algo_state, server, x_star, cohort,
                         c_active, c_p, t) -> tuple:
        """Sparse cohort aggregation for a stateful rule: per-client state
        rows are gathered/scattered at ``cohort`` only
        (``repro.scale.sparse_state``), so the round touches O(C) state.
        Stateful families are singletons (unique state signatures), so
        dispatch is always static. Returns ``(algo_state', server')``."""
        from repro.scale.sparse_state import cohort_branch

        if not (_is_static(algo_id) or len(self.names) == 1):
            raise ValueError(
                "cohort aggregation needs a static algo_id (stateful "
                f"families are singletons; got a traced id over {self.names})")
        idx = int(algo_id) if _is_static(algo_id) else 0
        branch = cohort_branch(self.names[idx], self)
        return branch(algo_state, server, x_star, cohort, c_active, c_p, t)

    def aggregate(self, algo_id, algo_state, server, clients, x_star, active,
                  p_t, t, use_kernel: bool = False) -> tuple:
        if use_kernel and self.fusable:
            return self._aggregate_fused(algo_id, algo_state, server,
                                         x_star, active, p_t)
        branches = [_DEFS[n].make_branch(self) for n in self.names]
        if _is_static(algo_id) or len(self.names) == 1:
            idx = int(algo_id) if _is_static(algo_id) else 0
            return branches[idx](algo_state, server, clients, x_star, active,
                                 p_t, t)
        return jax.lax.switch(algo_id, branches, algo_state, server, clients,
                              x_star, active, p_t, t)

    def _aggregate_fused(self, algo_id, algo_state, server, x_star, active,
                         p_t) -> tuple:
        """The fused-kernel aggregate: one backend-dispatched pass per leaf
        computes the new server params with the family's weighting branches
        selected by a (possibly traced) opcode INSIDE the kernel body, then
        one select updates the clients (postponed broadcast for fedpbc,
        instant for the FedAvg variants). Subsumes the ``lax.switch`` that
        evaluates every branch under vmap; the family's ``algo_state`` is
        empty and passes through untouched."""
        from repro.kernels.dispatch import fused_agg_pytree

        op, is_pbc = self.fused_op(algo_id)
        if _is_static(algo_id):
            bcast = active if is_pbc else jnp.ones_like(active)
        else:
            bcast = active | ~is_pbc
        new_server = fused_agg_pytree(x_star, active, op, server, p_t)
        # fedpbc: only active clients receive the new global model (the
        # postponed broadcast); every other member broadcasts to all m —
        # the all-ones mask makes bcast_where coincide with _tile.
        new_clients = bcast_where(bcast, new_server, x_star)
        return algo_state, new_server, new_clients

    def bind(self, algo_id: Union[int, jnp.ndarray] = 0,
             use_kernel: bool = False) -> Algorithm:
        """Fix the dispatch index and expose the historical ``Algorithm``
        interface. A python-int ``algo_id`` yields the exact per-algorithm
        trace; a traced one yields the family switch. ``use_kernel`` routes
        a fusable family's aggregation through the backend-dispatched fused
        kernel (``repro.kernels.dispatch``) instead of the XLA switch."""
        if _is_static(algo_id):
            name = self.names[int(algo_id)]
            needs_p = _DEFS[name].needs_p
        else:
            name = "+".join(self.names)
            needs_p = self.needs_p
        return Algorithm(
            name=name,
            init=self.init,
            client_start=lambda a, s, c: self.client_start(algo_id, a, s, c),
            aggregate=lambda a, s, c, xs, act, p, t: self.aggregate(
                algo_id, a, s, c, xs, act, p, t, use_kernel=use_kernel),
            needs_p=needs_p)


def as_algorithm(algorithm: Union[Algorithm, AlgorithmSpec],
                 algo_id=0, use_kernel: bool = False) -> Algorithm:
    """Normalize an ``Algorithm | AlgorithmSpec`` argument: specs are bound at
    ``algo_id`` (with the aggregation path picked by ``use_kernel``),
    algorithms pass through (their dispatch is already fixed)."""
    if isinstance(algorithm, AlgorithmSpec):
        return algorithm.bind(algo_id, use_kernel=use_kernel)
    return algorithm


# ---------------------------------------------------------------------------
# Single-algorithm factories (the historical constructors)
# ---------------------------------------------------------------------------


def fedpbc() -> Algorithm:
    return AlgorithmSpec(("fedpbc",)).bind(0)


def fedavg() -> Algorithm:
    return AlgorithmSpec(("fedavg",)).bind(0)


def fedavg_all() -> Algorithm:
    return AlgorithmSpec(("fedavg_all",)).bind(0)


def fedavg_known_p() -> Algorithm:
    return AlgorithmSpec(("fedavg_known_p",)).bind(0)


def fedau(K: int = 50) -> Algorithm:
    return AlgorithmSpec(("fedau",), fedau_K=K).bind(0)


def mifa() -> Algorithm:
    return AlgorithmSpec(("mifa",)).bind(0)


def f3ast(beta: float = 0.01, cap: int = 10) -> Algorithm:
    return AlgorithmSpec(("f3ast",), f3ast_beta=beta, f3ast_cap=cap).bind(0)


def fedpbc_m(beta: float = 0.8) -> Algorithm:
    return AlgorithmSpec(("fedpbc_m",), fedpbc_m_beta=beta).bind(0)


ALGORITHMS = {
    "fedpbc": fedpbc,
    "fedpbc_m": fedpbc_m,
    "fedavg": fedavg,
    "fedavg_all": fedavg_all,
    "fedau": fedau,
    "mifa": mifa,
    "fedavg_known_p": fedavg_known_p,
    "f3ast": f3ast,
}


def make_algorithm_spec(names: Tuple[str, ...],
                        cfg: FederationConfig = None) -> AlgorithmSpec:
    """Spec table for a family, with static knobs drawn from ``cfg``."""
    kw = {} if cfg is None else dict(
        fedau_K=cfg.fedau_K, f3ast_beta=cfg.f3ast_beta, f3ast_cap=cfg.f3ast_cap)
    return AlgorithmSpec(tuple(names), **kw)


def make_algorithm(cfg: FederationConfig) -> Algorithm:
    return make_algorithm_spec((cfg.algorithm,), cfg).bind(0)
