"""Device-resident data sources for the multi-round scan engine.

A ``DataSource`` is the functional counterpart of the host-side batch
generators in ``repro.data.synthetic``: the full dataset (or the generator's
parameters) lives on device, and one round's per-client batches are sampled
*inside* the jit program::

    sample(ds_state, round, key) -> (batches, ds_state)

``batches`` is the pytree ``round_fn`` expects — leading ``[m, s, ...]`` axes
(per client, per local step). Because sampling is pure and device-side, K
rounds can run under a single ``jax.lax.scan`` (``repro.core.federated
.run_rounds``) with no per-round host dispatch or H2D transfer.

Sources that need no evolving state return ``ds_state`` unchanged (an empty
tuple); randomness comes from the per-round ``key`` the engine derives via
``fold_in(data_key, round)``, so trajectories are reproducible and identical
between the scanned and sequential paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class DataSource:
    init: Callable[..., Any]      # (key) -> ds_state
    sample: Callable[..., Any]    # (ds_state, round, key) -> (batches, ds_state)
    name: str = ""
    # cohort mode (repro.scale): (ds_state, round, key, cohort [C] int32) ->
    # ([C, s, ...] batches, ds_state) — only the sampled clients' batches are
    # materialized, so per-round data memory is O(C) not O(m). With
    # cohort = arange(m) the draw is bit-for-bit the dense ``sample``.
    sample_cohort: Optional[Callable[..., Any]] = None


def classification_source(x, y, client_idx, *, local_steps: int,
                          batch_size: int) -> DataSource:
    """Device-resident sampler over a partitioned classification dataset.

    ``x [n, ...]``, ``y [n]`` and ``client_idx [m, per_client]`` are captured
    as jit constants; each round draws ``[m, s, b]`` examples with replacement
    from every client's shard (same distribution as the host-side
    ``federated_classification_batches``).
    """
    x = jnp.asarray(x)
    y = jnp.asarray(y)
    client_idx = jnp.asarray(client_idx)
    m, per_client = client_idx.shape

    def init(key):
        return ()

    def sample(ds_state, t, key):
        pick = jax.random.randint(
            key, (m, local_steps, batch_size), 0, per_client)
        sel = client_idx[jnp.arange(m)[:, None, None], pick]
        return {"x": x[sel], "y": y[sel]}, ds_state

    def sample_cohort(ds_state, t, key, cohort):
        C = cohort.shape[0]
        pick = jax.random.randint(
            key, (C, local_steps, batch_size), 0, per_client)
        sel = client_idx[cohort[:, None, None], pick]
        return {"x": x[sel], "y": y[sel]}, ds_state

    return DataSource(init, sample, "classification", sample_cohort)


def traced_classification_source(shared, *, local_steps: int,
                                 batch_size: int) -> DataSource:
    """Traced counterpart of ``classification_source``: nothing about the
    dataset is a jit constant.

    The dataset arrays travel in ``shared`` (``{"x": [n, ...], "y": [n]}``,
    typically traced jit inputs — the factory is meant to be called *inside*
    a traced function, mirroring the sweep engine's ``link_factory``), and the
    per-client partition travels in ``ds_state`` (``{"idx": [m, per_client]}``),
    so a Dirichlet-alpha re-partition or a dataset swap of the same shapes
    reuses the compiled program instead of rebuilding it.

    ``init(key, data) -> ds_state`` takes the per-trajectory data pytree (the
    batched-runner protocol; the key is accepted for signature symmetry and
    unused). ``sample`` draws the same indices as ``classification_source`` —
    given equal arrays the two sources produce bit-for-bit equal batches.
    """

    def init(key, data):
        return data

    def sample(ds_state, t, key):
        client_idx = ds_state["idx"]
        m, per_client = client_idx.shape
        pick = jax.random.randint(
            key, (m, local_steps, batch_size), 0, per_client)
        sel = client_idx[jnp.arange(m)[:, None, None], pick]
        return {"x": shared["x"][sel], "y": shared["y"][sel]}, ds_state

    def sample_cohort(ds_state, t, key, cohort):
        client_idx = ds_state["idx"]
        per_client = client_idx.shape[1]
        C = cohort.shape[0]
        pick = jax.random.randint(
            key, (C, local_steps, batch_size), 0, per_client)
        sel = client_idx[cohort[:, None, None], pick]
        return {"x": shared["x"][sel], "y": shared["y"][sel]}, ds_state

    return DataSource(init, sample, "classification_traced", sample_cohort)


def traced_lm_source(shared, *, local_steps: int,
                     batch_size: int) -> DataSource:
    """Traced next-token-prediction counterpart of
    ``traced_classification_source``.

    The corpus travels in ``shared`` (``{"toks": [n, T+1]}`` int32 sequences,
    each of length context+1 so tokens/labels come from one slice), the
    per-client Dirichlet partition in ``ds_state`` (``{"idx":
    [m, per_client]}`` sequence indices). Each round draws ``[m, s, b]``
    sequences with replacement from every client's shard — the index draw is
    the same ``randint`` protocol as the classification sources, so the LM
    task rides the sweep engine's compiled programs with nothing about the
    dataset baked in as a constant.
    """

    def init(key, data):
        return data

    def _slice(seqs):
        return {"tokens": seqs[..., :-1], "labels": seqs[..., 1:]}

    def sample(ds_state, t, key):
        client_idx = ds_state["idx"]
        m, per_client = client_idx.shape
        pick = jax.random.randint(
            key, (m, local_steps, batch_size), 0, per_client)
        sel = client_idx[jnp.arange(m)[:, None, None], pick]
        return _slice(shared["toks"][sel]), ds_state

    def sample_cohort(ds_state, t, key, cohort):
        client_idx = ds_state["idx"]
        per_client = client_idx.shape[1]
        C = cohort.shape[0]
        pick = jax.random.randint(
            key, (C, local_steps, batch_size), 0, per_client)
        sel = client_idx[cohort[:, None, None], pick]
        return _slice(shared["toks"][sel]), ds_state

    return DataSource(init, sample, "lm_traced", sample_cohort)


def lm_source(*, num_clients: int, local_steps: int, batch: int, seq: int,
              vocab: int, client_shift: bool = True,
              memory_shape: Optional[Tuple[int, ...]] = None) -> DataSource:
    """Synthetic non-IID token streams generated on device.

    Mirrors ``federated_lm_batches``: each client draws tokens from its own
    half-vocab slice (offset drawn once at ``init``). ``memory_shape`` appends
    a constant ``memory`` leaf of shape ``[m, s, *memory_shape]`` for
    vlm/audio model families.
    """
    m, s = num_clients, local_steps

    def init(key):
        lo = (jax.random.randint(key, (m,), 0, vocab // 2)
              if client_shift else jnp.zeros((m,), jnp.int32))
        return {"lo": lo.astype(jnp.int32)}

    def sample(ds_state, t, key):
        toks = ds_state["lo"][:, None, None, None] + jax.random.randint(
            key, (m, s, batch, seq), 0, vocab // 2)
        toks = toks.astype(jnp.int32)
        batches = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
        if memory_shape is not None:
            batches["memory"] = 0.1 * jnp.ones((m, s) + tuple(memory_shape))
        return batches, ds_state

    def sample_cohort(ds_state, t, key, cohort):
        C = cohort.shape[0]
        toks = ds_state["lo"][cohort][:, None, None, None] + jax.random.randint(
            key, (C, s, batch, seq), 0, vocab // 2)
        toks = toks.astype(jnp.int32)
        batches = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=-1)}
        if memory_shape is not None:
            batches["memory"] = 0.1 * jnp.ones((C, s) + tuple(memory_shape))
        return batches, ds_state

    return DataSource(init, sample, "lm", sample_cohort)


def fixed_source(batches: Pytree) -> DataSource:
    """Every round sees the same ``[m, s, ...]`` batch pytree (the quadratic
    counterexample setups, where each client's objective is deterministic)."""
    batches = jax.tree.map(jnp.asarray, batches)

    def init(key):
        return ()

    def sample(ds_state, t, key):
        return batches, ds_state

    def sample_cohort(ds_state, t, key, cohort):
        return jax.tree.map(lambda b: b[cohort], batches), ds_state

    return DataSource(init, sample, "fixed", sample_cohort)
