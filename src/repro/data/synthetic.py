"""Synthetic datasets standing in for SVHN/CIFAR-10/CINIC-10 (offline env):
a 10-class Gaussian-cluster image-classification task with the same shape
semantics (non-IID Dirichlet split, per-client equal volume), plus a token-LM
stream for transformer-scale federated training.
"""
from __future__ import annotations

import numpy as np


def make_classification_data(seed: int, *, num_classes=10, dim=64,
                             n_per_class=600, noise=1.0, sep=2.0):
    """Gaussian clusters: x ~ N(sep * mu_c, noise^2 I). Returns (x, y)."""
    rng = np.random.default_rng(seed)
    mus = rng.normal(size=(num_classes, dim))
    mus /= np.linalg.norm(mus, axis=1, keepdims=True)
    xs, ys = [], []
    for c in range(num_classes):
        xs.append(sep * mus[c] + noise * rng.normal(size=(n_per_class, dim)))
        ys.append(np.full(n_per_class, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def federated_classification_batches(rng, x, y, client_idx, *, local_steps,
                                     batch_size):
    """Sample one round of per-client mini-batches: [m, s, b, ...]."""
    m, _ = client_idx.shape
    xs = np.zeros((m, local_steps, batch_size) + x.shape[1:], np.float32)
    ys = np.zeros((m, local_steps, batch_size), np.int32)
    for i in range(m):
        pick = rng.integers(0, client_idx.shape[1], size=(local_steps, batch_size))
        sel = client_idx[i][pick]
        xs[i] = x[sel]
        ys[i] = y[sel]
    return {"x": xs, "y": ys}


def federated_lm_batches(rng, *, num_clients, local_steps, batch, seq,
                         vocab, client_shift=True):
    """Synthetic non-IID token streams: each client's tokens are drawn from a
    client-specific Zipf-ish slice of the vocabulary (mimics Dirichlet
    heterogeneity at the LM level)."""
    lo = (rng.integers(0, vocab // 2, size=num_clients)
          if client_shift else np.zeros(num_clients, np.int64))
    toks = np.zeros((num_clients, local_steps, batch, seq), np.int32)
    for i in range(num_clients):
        toks[i] = lo[i] + rng.integers(0, vocab // 2,
                                       size=(local_steps, batch, seq))
    labels = np.roll(toks, -1, axis=-1)
    return {"tokens": toks, "labels": labels}
