from repro.data.partition import dirichlet_partition
from repro.data.sources import (
    DataSource,
    classification_source,
    fixed_source,
    lm_source,
    traced_classification_source,
)
from repro.data.synthetic import (
    federated_classification_batches,
    federated_lm_batches,
    make_classification_data,
)

__all__ = [
    "dirichlet_partition",
    "make_classification_data",
    "federated_classification_batches",
    "federated_lm_batches",
    "DataSource",
    "classification_source",
    "fixed_source",
    "lm_source",
    "traced_classification_source",
]
