"""Non-IID data partitioning (Hsu et al. 2019, used by the paper §7.2).

Each client's class mixture nu_i ~ Dirichlet(alpha); every client holds the
same data volume (paper setup)."""
from __future__ import annotations

import numpy as np


def dirichlet_partition(rng: np.random.Generator, labels: np.ndarray,
                        num_clients: int, alpha: float, per_client: int):
    """Returns (indices [m, per_client], nu [m, C])."""
    classes = np.unique(labels)
    C = len(classes)
    by_class = {c: rng.permutation(np.where(labels == c)[0]).tolist() for c in classes}
    nu = rng.dirichlet(np.full(C, alpha), size=num_clients)
    out = np.zeros((num_clients, per_client), dtype=np.int64)
    for i in range(num_clients):
        counts = rng.multinomial(per_client, nu[i])
        got = []
        for c, n in zip(classes, counts):
            pool = by_class[int(c)]
            take = pool[:n]
            if len(take) < n:  # recycle if exhausted (sampling w/ replacement)
                extra = rng.choice(np.where(labels == c)[0], n - len(take))
                take = take + list(extra)
            by_class[int(c)] = pool[n:]
            got.extend(take)
        while len(got) < per_client:
            got.append(int(rng.integers(len(labels))))
        out[i] = np.array(got[:per_client])
    return out, nu
