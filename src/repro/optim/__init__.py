from repro.optim.optimizers import Optimizer, adam, sgd
from repro.optim.schedules import constant, paper_decay

__all__ = ["Optimizer", "adam", "sgd", "constant", "paper_decay"]
