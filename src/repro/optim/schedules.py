"""Learning-rate schedules. ``paper_decay`` is the paper's Appendix-B schedule
eta_t = eta_0 / sqrt(t/10 + 1)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(eta0: float):
    return lambda step: jnp.asarray(eta0, jnp.float32)


def paper_decay(eta0: float, div: float = 10.0):
    def sched(step):
        t = jnp.asarray(step, jnp.float32)
        return eta0 / jnp.sqrt(t / div + 1.0)
    return sched
