"""Learning-rate schedules. ``paper_decay`` is the paper's Appendix-B schedule
eta_t = eta_0 / sqrt(t/10 + 1).

``eta0`` may be a python float *or a traced scalar*: the sweep engine builds
its optimizer inside the compiled program from a traced per-point base LR
(``repro.experiments.sweep.make_batched_run_rounds``), so an LR ablation is
served by one compile. Both schedules are pure arithmetic in ``eta0``, which
is what makes the traced form bit-for-bit identical to the baked-constant
form (asserted in ``tests/test_traced_axes.py``).
"""
from __future__ import annotations

import jax.numpy as jnp


def constant(eta0):
    return lambda step: jnp.asarray(eta0, jnp.float32)


def paper_decay(eta0, div: float = 10.0):
    def sched(step):
        t = jnp.asarray(step, jnp.float32)
        return eta0 / jnp.sqrt(t / div + 1.0)
    return sched
