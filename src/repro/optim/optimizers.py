"""Minimal functional optimizers (SGD + momentum, Adam) over pytrees."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    """``lr``: float or schedule fn step->lr."""
    sched = lr if callable(lr) else (lambda _: lr)

    def init(params):
        st = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            st["mu"] = jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)
        return st

    def update(params, state, grads):
        eta = sched(state["step"])
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state["mu"], grads)
            params = jax.tree.map(lambda p, m: (p - eta * m).astype(p.dtype), params, mu)
            return params, {"step": state["step"] + 1, "mu": mu}
        params = jax.tree.map(lambda p, g: (p - eta * g).astype(p.dtype), params, grads)
        return params, {"step": state["step"] + 1}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    sched = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda x: jnp.zeros_like(x, jnp.float32)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(z, params),
            "v": jax.tree.map(z, params),
        }

    def update(params, state, grads):
        step = state["step"] + 1
        eta = sched(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p - eta * u).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
