"""Mixture-of-Experts FFN with top-k routing.

Two dispatch strategies (``MoEConfig.dispatch``):

- ``einsum``  — GShard/Switch-style one-hot capacity dispatch. Baseline:
  lowers everywhere and shards cleanly (experts over the "model" axis when
  divisible), but the dispatch/combine einsums contribute O(T * E*C * d)
  HLO FLOPs which can rival the expert matmuls themselves. This is the
  paper-era TPU formulation and our roofline *baseline*.
- ``scatter`` — gather/scatter capacity dispatch: tokens are routed into the
  [E, C, d] buffers with one scatter-add and combined with one gather, both
  memory-bound. This is the beyond-baseline §Perf variant (hillclimb H1).

Both are dropless up to the capacity factor; overflow tokens fall back to the
residual stream (standard capacity semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dtype_of, init_dense


def moe_init(key, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": init_dense(ks[0], d, e, jnp.float32),
        "up": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * d ** -0.5).astype(dt),
        "down": (jax.random.normal(ks[2], (e, f, d), jnp.float32) * f ** -0.5).astype(dt),
    }
    if cfg.gated_mlp:
        p["gate"] = (jax.random.normal(ks[3], (e, d, f), jnp.float32) * d ** -0.5).astype(dt)
    return p


def _router(p, x2d, cfg: ModelConfig):
    """Return top-k expert ids, renormalized gates, and aux load-balance loss."""
    e = cfg.moe.num_experts
    k = cfg.moe.top_k
    logits = x2d.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)  # [T,k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    me = probs.mean(0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (x2d.shape[0] * k)
    aux = e * jnp.sum(me * ce)
    return idx, gates, aux


def _expert_ffn(p, xe, gated):
    """xe [E, C, d] -> [E, C, d] through per-expert (gated) MLP."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["up"])
    if gated:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["down"])


def _capacity(cfg: ModelConfig, t: int) -> int:
    m = cfg.moe
    return max(1, int(m.capacity_factor * m.top_k * t / m.num_experts))


def moe_apply(p, x, cfg: ModelConfig):
    """x [B, T, d] -> (out [B, T, d], aux_loss scalar).

    GShard-style grouping: each batch row is a dispatch group, so the one-hot
    dispatch tensor is [B, T, E, C_row] with per-row capacity — never a
    global [B*T, E, C] (which would be petabytes at 1M tokens)."""
    b, t, d = x.shape
    if b > 1:
        out, aux = jax.vmap(lambda row: _moe_group(p, row[None], cfg))(x)
        return out[:, 0], aux.mean()
    return _moe_group(p, x, cfg)


def _moe_group(p, x, cfg: ModelConfig):
    b, t, d = x.shape
    x2d = x.reshape(b * t, d)
    idx, gates, aux = _router(p, x2d, cfg)
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    cap = _capacity(cfg, b * t)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)          # [T, k, E]
    # position of each (token, choice) within its expert buffer
    pos = jnp.cumsum(onehot.reshape(-1, e), axis=0).reshape(-1, k, e) - 1.0
    pos = jnp.sum(pos * onehot, axis=-1)                         # [T, k]
    keep = (pos < cap)
    gates = gates * keep

    if cfg.moe.dispatch == "einsum":
        poh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[..., None]
        disp = jnp.einsum("tke,tkc->tec", onehot, poh)           # [T, E, C] 0/1
        comb = jnp.einsum("tke,tkc,tk->tec", onehot, poh, gates)
        xe = jnp.einsum("tec,td->ecd", disp, x2d.astype(jnp.float32)).astype(x.dtype)
        ye = _expert_ffn(p, xe, cfg.gated_mlp)
        out = jnp.einsum("tec,ecd->td", comb, ye.astype(jnp.float32))
    else:  # scatter
        flat_slot = (idx * cap + pos.astype(jnp.int32)).reshape(-1)   # [T*k]
        safe_slot = jnp.where(keep.reshape(-1), flat_slot, e * cap)   # overflow row
        xk = jnp.repeat(x2d.astype(jnp.float32), k, axis=0)           # [T*k, d]
        buf = jnp.zeros((e * cap + 1, d), jnp.float32).at[safe_slot].add(xk)
        xe = buf[: e * cap].reshape(e, cap, d).astype(x.dtype)
        ye = _expert_ffn(p, xe, cfg.gated_mlp)
        yk = ye.reshape(e * cap, d)[jnp.clip(flat_slot, 0, e * cap - 1)]  # [T*k, d]
        yk = yk.astype(jnp.float32) * gates.reshape(-1, 1)
        out = yk.reshape(b * t, k, d).sum(axis=1)

    return out.reshape(b, t, d).astype(x.dtype), aux
