"""Mamba-style selective SSM block (used by the jamba hybrid).

The recurrence ``h_t = a_t * h_{t-1} + b_t`` (elementwise over [d_inner, N])
is evaluated chunk-parallel: ``lax.scan`` over time chunks carrying the state,
``lax.associative_scan`` inside each chunk. This keeps both the HLO and the
activation memory bounded at 500k sequence lengths (state never materializes
beyond one chunk).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.flags import analysis_chunk, scan_unroll
from repro.models.layers import dtype_of, init_dense


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = cfg.d_model * s.expand
    dtr = s.dt_rank or -(-cfg.d_model // 16)
    return di, s.state_dim, dtr, s.conv_width


def ssm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di, n, dtr, cw = _dims(cfg)
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": init_dense(ks[0], d, 2 * di, dt),
        "conv": (jax.random.normal(ks[1], (cw, di), jnp.float32) * 0.2).astype(dt),
        "conv_bias": jnp.zeros((di,), dt),
        "x_proj": init_dense(ks[2], di, dtr + 2 * n, dt),
        "dt_proj": init_dense(ks[3], dtr, di, jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((di,), 0.01, jnp.float32))),
        "a_log": jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))[None, :].repeat(di, 0),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": init_dense(ks[4], di, d, dt),
    }


def _fused_scan(xc, dt, b_in, c_in, a, h0, chunk):
    """Fused selective scan (§Perf H3): the [B, T, di, N] abar/bbar tensors
    are materialized only per-chunk inside the scan body, and the output
    contraction with C happens in the same body — peak state memory drops
    from O(T * di * N) to O(chunk * di * N).

    xc, dt: [B, T, di]; b_in, c_in: [B, T, N]; a: [di, N]; h0: [B, di, N].
    Returns (y [B, T, di], h_T)."""
    bsz, t, di = xc.shape
    n = a.shape[1]
    chunk = min(analysis_chunk(chunk, t, max_trips=8), t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        z3 = ((0, 0), (0, pad), (0, 0))
        xc = jnp.pad(xc, z3)
        dt = jnp.pad(dt, z3)
        b_in = jnp.pad(b_in, z3)
        c_in = jnp.pad(c_in, z3)

    def to_chunks(x):
        return x.reshape(bsz, nc, chunk, x.shape[-1]).transpose(1, 0, 2, 3)

    xs = tuple(map(to_chunks, (xc, dt, b_in, c_in)))

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a2 * a1, a2 * b1 + b2

    def step(h, xs_c):
        xc_c, dt_c, b_c, c_c = xs_c                      # [B, C, di], [B, C, N]
        abar = jnp.exp(dt_c[..., None] * a[None, None])  # [B, C, di, N]
        bbar = dt_c[..., None] * b_c[:, :, None, :] * xc_c[..., None]
        aa, bb = jax.lax.associative_scan(combine, (abar, bbar), axis=1)
        h_all = aa * h[:, None] + bb
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, c_c)
        return h_all[:, -1], y_c

    step = jax.checkpoint(step, prevent_cse=False)
    h_t, yc = jax.lax.scan(step, h0, xs, unroll=scan_unroll())
    y = yc.transpose(1, 0, 2, 3).reshape(bsz, nc * chunk, di)
    return y[:, :t], h_t


def ssm_apply(p, x, cfg: ModelConfig, state=None, chunk=128):
    """x [B, T, d]. state: None (train/prefill) or dict (decode carry).

    Returns (out [B, T, d], new_state).
    """
    b, t, d = x.shape
    di, n, dtr, cw = _dims(cfg)
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)  # [B,T,di]

    # depthwise causal conv (width cw)
    if state is None:
        conv_in = jnp.pad(xs, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        conv_in = jnp.concatenate([state["conv"], xs], axis=1)
    windows = jnp.stack([conv_in[:, i : i + t] for i in range(cw)], axis=0)  # [cw,B,T,di]
    xc = jnp.einsum("wbtd,wd->btd", windows.astype(jnp.float32),
                    p["conv"].astype(jnp.float32)) + p["conv_bias"].astype(jnp.float32)
    xc = jax.nn.silu(xc).astype(x.dtype)

    proj = xc @ p["x_proj"]
    dt_in, b_in, c_in = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["dt_proj"] + p["dt_bias"])  # [B,T,di]
    a = -jnp.exp(p["a_log"])                                   # [di, N]

    h0 = state["h"] if state is not None else jnp.zeros((b, di, n), jnp.float32)
    y, h_t = _fused_scan(xc.astype(jnp.float32), dt,
                         b_in.astype(jnp.float32), c_in.astype(jnp.float32),
                         a, h0, chunk)
    y = y + p["d_skip"] * xc.astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = y.astype(x.dtype) @ p["out_proj"]
    new_state = {"conv": conv_in[:, -(cw - 1):], "h": h_t}
    return out, new_state


def ssm_init_state(cfg: ModelConfig, batch):
    di, n, _, cw = _dims(cfg)
    dt = dtype_of(cfg)
    return {"conv": jnp.zeros((batch, cw - 1, di), dt), "h": jnp.zeros((batch, di, n), jnp.float32)}
