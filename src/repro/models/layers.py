"""Basic model layers: norms, rope, MLPs, projections, init helpers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def rms_norm(x, weight, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return ((1.0 + weight.astype(jnp.float32)) * out).astype(x.dtype)


def init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rope_angles(positions, head_dim, theta):
    """positions [...,] int32 -> cos,sin [..., head_dim//2] in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., T, H, D]; cos/sin [..., T, D/2] broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


def mlp_init(key, cfg: ModelConfig, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 3)
    p = {"up": init_dense(ks[0], d, f, dt), "down": init_dense(ks[1], f, d, dt)}
    if cfg.gated_mlp:
        p["gate"] = init_dense(ks[2], d, f, dt)
    return p


def mlp_apply(p, x, gated=True):
    h = x @ p["up"]
    if gated:
        h = jax.nn.silu(x @ p["gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["down"]


def softcap(logits, cap):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)
