"""Model assembly for all assigned architecture families.

Design:
- Parameters are plain nested dicts (pytrees); everything is functional.
- The layer stack is organised in *periods*: the smallest repeating pattern of
  layer kinds (jamba: 8 = 7 mamba + 1 attn; gemma2: 2 = local+global; VLM: 5 =
  4 self + 1 cross; llama4: 2 = dense+MoE; plain dense: 1). Period parameters
  are stacked on a leading axis and the stack is ``lax.scan``-ed, so the HLO
  holds one period regardless of depth — essential for compiling 88-100 layer
  configs against a 512-device mesh.
- ``forward``  : train/prefill, full-sequence.
- ``decode_step``: one token against a KV/SSM/RWKV cache (serve path).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import rwkv as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import attention, cross_attention, decode_attention
from repro.models.layers import (
    apply_rope,
    dtype_of,
    init_dense,
    mlp_apply,
    mlp_init,
    rms_norm,
    rope_angles,
    softcap,
)
from repro.models.flags import scan_unroll
from repro.models.moe import moe_apply, moe_init
from repro.sharding.specs import maybe_constrain


# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------


def period_length(cfg: ModelConfig) -> int:
    p = 1
    if cfg.family == "hybrid":
        p = math.lcm(max(cfg.attn_every, 1), max(cfg.moe_every, 1) if cfg.moe else 1)
    elif cfg.family == "vlm" and cfg.cross_attn_every:
        p = cfg.cross_attn_every
    elif cfg.attention.pattern == "local_global":
        p = 2
    if cfg.moe and cfg.family != "hybrid":
        p = math.lcm(p, max(cfg.moe_every, 1))
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    return p


def attn_kind(cfg: ModelConfig, layer_idx: int) -> str:
    pat = cfg.attention.pattern
    if pat == "local_global":
        return "swa" if layer_idx % 2 == 0 else "full"
    return pat


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _attn_init(key, cfg: ModelConfig):
    d, hd = cfg.d_model, cfg.head_dim
    a = cfg.attention
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, a.num_heads * hd, dt),
        "wk": init_dense(ks[1], d, a.num_kv_heads * hd, dt),
        "wv": init_dense(ks[2], d, a.num_kv_heads * hd, dt),
        "wo": init_dense(ks[3], a.num_heads * hd, d, dt),
    }


def _layer_init(key, cfg: ModelConfig, layer_idx: int):
    kind = cfg.layer_kind(layer_idx)
    dt = dtype_of(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"ln1": jnp.zeros((d,), dt)}
    if kind == "rwkv":
        p["tmix"] = rwkv_mod.rwkv_init(ks[0], cfg)
        p["ln2"] = jnp.zeros((d,), dt)
        return p  # channel-mix params live inside tmix dict (ck/cv/cr)
    if kind == "ssm":
        p["ssm"] = ssm_mod.ssm_init(ks[0], cfg)
    else:
        p["attn"] = _attn_init(ks[0], cfg)
    if kind == "cross":
        p["lnc"] = jnp.zeros((d,), dt)
        p["cross"] = _attn_init(ks[1], cfg)
        # VLM: zero-init gate (Llama-3.2 style); enc-dec: open gate
        gate0 = 2.0 if cfg.family == "audio" else 0.0
        p["cross_gate"] = jnp.asarray(gate0, jnp.float32)
    p["ln2"] = jnp.zeros((d,), dt)
    if cfg._is_moe_layer(layer_idx):
        p["moe"] = moe_init(ks[2], cfg)
    else:
        p["ffn"] = mlp_init(ks[2], cfg)
    return p


def init_params(key, cfg: ModelConfig):
    P = period_length(cfg)
    n_periods = cfg.num_layers // P
    dt = dtype_of(cfg)
    k_emb, k_blocks, k_head, k_enc, k_extra = jax.random.split(key, 5)

    def period_init(k):
        kk = jax.random.split(k, P)
        return tuple(_layer_init(kk[i], cfg, i) for i in range(P))

    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(dt),
        "blocks": jax.vmap(period_init)(jax.random.split(k_blocks, n_periods)),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_dense(k_head, cfg.d_model, cfg.vocab_size, dt)
    if cfg.family == "vlm":
        params["image_proj"] = init_dense(k_extra, cfg.d_model, cfg.d_model, dt)
    if cfg.family == "audio":
        def enc_layer_init(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": jnp.zeros((cfg.d_model,), dt),
                "attn": _attn_init(k1, cfg),
                "ln2": jnp.zeros((cfg.d_model,), dt),
                "ffn": mlp_init(k2, cfg),
            }
        params["encoder"] = jax.vmap(enc_layer_init)(
            jax.random.split(k_enc, cfg.encoder_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
        params["audio_proj"] = init_dense(k_extra, cfg.d_model, cfg.d_model, dt)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def _self_attn_block(p, x, cfg, kind, positions):
    a = cfg.attention
    hd = cfg.head_dim
    b, t, _ = x.shape
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(b, t, a.num_heads, hd)
    k = (h @ p["attn"]["wk"]).reshape(b, t, a.num_kv_heads, hd)
    v = (h @ p["attn"]["wv"]).reshape(b, t, a.num_kv_heads, hd)
    cos, sin = rope_angles(positions, hd, a.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = attention(q, k, v, kind=kind, window=a.window,
                  logit_softcap=a.logit_softcap)
    return x + o.reshape(b, t, -1) @ p["attn"]["wo"]


def _cross_block(p, x, cfg, memory):
    a = cfg.attention
    hd = cfg.head_dim
    b, t, _ = x.shape
    h = rms_norm(x, p["lnc"], cfg.norm_eps)
    q = (h @ p["cross"]["wq"]).reshape(b, t, a.num_heads, hd)
    k = (memory @ p["cross"]["wk"]).reshape(b, memory.shape[1], a.num_kv_heads, hd)
    v = (memory @ p["cross"]["wv"]).reshape(b, memory.shape[1], a.num_kv_heads, hd)
    o = cross_attention(q, k, v)
    gate = jnp.tanh(p["cross_gate"]).astype(x.dtype)
    return x + gate * (o.reshape(b, t, -1) @ p["cross"]["wo"])


def _ffn_block(p, x, cfg):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        out, aux = moe_apply(p["moe"], h, cfg)
        return x + out, aux
    return x + mlp_apply(p["ffn"], h, cfg.gated_mlp), jnp.zeros((), jnp.float32)


def _apply_layer(p, x, cfg, layer_idx, positions, memory):
    kind = cfg.layer_kind(layer_idx)
    if kind == "rwkv":
        h, _ = rwkv_mod.rwkv_time_mix(p["tmix"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        h, _ = rwkv_mod.rwkv_channel_mix(p["tmix"], rms_norm(x, p["ln2"], cfg.norm_eps))
        return x + h, jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, _ = ssm_mod.ssm_apply(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        x = x + h
        return _ffn_block(p, x, cfg)
    x = _self_attn_block(p, x, cfg, attn_kind(cfg, layer_idx), positions)
    if kind == "cross":
        x = _cross_block(p, x, cfg, memory)
    return _ffn_block(p, x, cfg)


def _encode_audio(params, cfg, frames):
    x = frames @ params["audio_proj"]
    positions = jnp.arange(frames.shape[1])

    def body(h, lp):
        h = _self_attn_block(
            {"ln1": lp["ln1"], "attn": lp["attn"]}, h, cfg, "full", positions)
        h2 = rms_norm(h, lp["ln2"], cfg.norm_eps)
        return h + mlp_apply(lp["ffn"], h2, cfg.gated_mlp), None

    x, _ = jax.lax.scan(lambda h, lp: body(h, lp), x, params["encoder"],
                        unroll=scan_unroll())
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, tokens, *, memory=None, remat=False):
    """tokens [B, T] -> logits [B, T, V].

    ``memory``: image patch embeddings [B, I, D] (vlm), audio frame
    embeddings [B, F, D] (audio) — the stubbed modality frontends.
    ``remat``: checkpoint each period (training path) so the scan saves only
    the residual carries, not per-layer attention/FFN intermediates.
    """
    x = params["embed"][tokens].astype(dtype_of(cfg))
    positions = jnp.arange(tokens.shape[1])
    if cfg.family == "vlm":
        memory = memory @ params["image_proj"]
    elif cfg.family == "audio":
        memory = _encode_audio(params, cfg, memory)
    P = period_length(cfg)

    def period_body(carry, block):
        x, aux = carry
        x = maybe_constrain(x)  # sequence-parallel residual (no-op w/o mesh ctx)
        for i in range(P):
            x, a = _apply_layer(block[i], x, cfg, i, positions, memory)
            aux = aux + a
        return (maybe_constrain(x), aux), None

    if remat:
        period_body = jax.checkpoint(period_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(period_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=scan_unroll())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    logits = (x @ head) if head is not None else (x @ params["embed"].T)
    logits = softcap(logits.astype(jnp.float32), cfg.final_softcap)
    return logits, aux


def hidden_forward(params, cfg: ModelConfig, tokens, *, memory=None, remat=False):
    """Like ``forward`` but stops before the LM head: returns (hidden, aux)."""
    x = params["embed"][tokens].astype(dtype_of(cfg))
    positions = jnp.arange(tokens.shape[1])
    if cfg.family == "vlm":
        memory = memory @ params["image_proj"]
    elif cfg.family == "audio":
        memory = _encode_audio(params, cfg, memory)
    P = period_length(cfg)

    def period_body(carry, block):
        x, aux = carry
        x = maybe_constrain(x)
        for i in range(P):
            x, a = _apply_layer(block[i], x, cfg, i, positions, memory)
            aux = aux + a
        return (maybe_constrain(x), aux), None

    if remat:
        period_body = jax.checkpoint(period_body, prevent_cse=False)
    (x, aux), _ = jax.lax.scan(period_body, (x, jnp.zeros((), jnp.float32)),
                               params["blocks"], unroll=scan_unroll())
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(params, cfg: ModelConfig, batch, *, remat=True, ce_chunk=512):
    """batch: {'tokens': [B,T], 'labels': [B,T], optional 'memory'}.

    Cross-entropy is computed in token chunks under jax.checkpoint so the
    [B, T, V] logits tensor (tens of GB at 128k-256k vocab) never fully
    materializes — only one [B, ce_chunk, V] chunk is live at a time.
    """
    hidden, aux = hidden_forward(params, cfg, batch["tokens"],
                                 memory=batch.get("memory"), remat=remat)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    labels = batch["labels"]
    b, t, d = hidden.shape

    def chunk_ce(h_chunk, y_chunk):
        logits = softcap((h_chunk @ head).astype(jnp.float32), cfg.final_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_chunk[..., None], axis=-1)[..., 0]
        return (lse - gold).sum()

    chunk_ce = jax.checkpoint(chunk_ce, prevent_cse=False)
    ce_chunk = min(ce_chunk, t)
    n = -(-t // ce_chunk)
    pad = n * ce_chunk - t
    hp = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0))) if pad else hidden
    yp = jnp.pad(labels, ((0, 0), (0, pad))) if pad else labels
    # padded labels index 0 against padded (zero) hidden rows: their CE is a
    # constant log(V) offset; mask by weighting
    hc = hp.reshape(b, n, ce_chunk, d).transpose(1, 0, 2, 3)
    yc = yp.reshape(b, n, ce_chunk).transpose(1, 0, 2)

    def body(tot, xs):
        h_, y_ = xs
        return tot + chunk_ce(h_, y_), None

    if pad:
        valid = jnp.arange(n * ce_chunk) < t
        # simplest correct handling: compute full-seq in one chunk when padded
        logits = softcap((hidden @ head).astype(jnp.float32), cfg.final_softcap)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = (lse - gold).mean()
        return ce + 0.01 * aux
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, yc),
                          unroll=scan_unroll())
    ce = tot / (b * t)
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# Decode (serve path)
# ---------------------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Cache pytree matching the period structure (leading axis = n_periods).

    Windowed layers (swa / chunked) get a ring buffer of size ``window``,
    which is what bounds long_500k decode memory for mixtral/gemma2/llama4."""
    P = period_length(cfg)
    n_periods = cfg.num_layers // P
    a = cfg.attention
    hd = cfg.head_dim
    dt = dtype_of(cfg)

    def layer_cache(i):
        kind = cfg.layer_kind(i)
        if kind == "rwkv":
            nh = cfg.d_model // cfg.rwkv.head_dim
            return {
                "s": jnp.zeros((n_periods, batch, nh, cfg.rwkv.head_dim, cfg.rwkv.head_dim), jnp.float32),
                "last": jnp.zeros((n_periods, batch, 1, cfg.d_model), dt),
                "clast": jnp.zeros((n_periods, batch, 1, cfg.d_model), dt),
            }
        if kind == "ssm":
            st = ssm_mod.ssm_init_state(cfg, batch)
            return jax.tree.map(lambda x: jnp.zeros((n_periods,) + x.shape, x.dtype), st)
        eff = max_len
        if attn_kind(cfg, i) in ("swa", "chunked"):
            eff = min(max_len, a.window)
        return {
            "k": jnp.zeros((n_periods, batch, eff, a.num_kv_heads, hd), dt),
            "v": jnp.zeros((n_periods, batch, eff, a.num_kv_heads, hd), dt),
        }

    return tuple(layer_cache(i) for i in range(P))


def _decode_attn_layer(p, x, cfg, kind, cache, pos):
    """One-token self-attention against cache; returns (x, new_cache)."""
    a = cfg.attention
    hd = cfg.head_dim
    b = x.shape[0]
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(b, 1, a.num_heads, hd)
    k = (h @ p["attn"]["wk"]).reshape(b, 1, a.num_kv_heads, hd)
    v = (h @ p["attn"]["wv"]).reshape(b, 1, a.num_kv_heads, hd)
    cos, sin = rope_angles(pos[None], hd, a.rope_theta)
    q = apply_rope(q, cos[None], sin[None])
    k = apply_rope(k, cos[None], sin[None])
    s_max = cache["k"].shape[1]
    slot = pos % s_max if kind in ("swa", "chunked") else jnp.minimum(pos, s_max - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    if kind in ("swa", "chunked"):
        # Ring buffer of size `window`. Make it chronological (oldest first):
        # once full, the oldest entry sits at slot+1.
        eff_len = jnp.minimum(pos + 1, s_max)
        shift = jnp.where(pos + 1 >= s_max, -(slot + 1), 0)
        # chunked attends only within the current block: last (pos%window)+1
        # tokens; swa attends the whole (<= window) ring.
        keep = (pos % a.window) + 1 if kind == "chunked" else eff_len
        keep = jnp.minimum(keep, eff_len)
        drop = eff_len - keep
        ckl = jnp.roll(ck, shift - drop, axis=1)
        cvl = jnp.roll(cv, shift - drop, axis=1)
        o = decode_attention(q, ckl, cvl, keep, kind="full",
                             logit_softcap=a.logit_softcap)
    else:
        o = decode_attention(q, ck, cv, pos + 1, kind=kind, window=a.window,
                             logit_softcap=a.logit_softcap)
    x = x + o.reshape(b, 1, -1) @ p["attn"]["wo"]
    return x, {"k": ck, "v": cv}


def decode_step(params, cfg: ModelConfig, token, cache, pos, *, memory=None):
    """token [B, 1] int32; cache from make_cache; pos scalar int32 (= tokens
    already in cache). Returns (logits [B, 1, V], new_cache)."""
    x = params["embed"][token].astype(dtype_of(cfg))
    if cfg.family == "vlm":
        memory = memory @ params["image_proj"]
    elif cfg.family == "audio":
        memory = _encode_audio(params, cfg, memory)
    P = period_length(cfg)

    def period_body(x, xs):
        block, pcache = xs
        new_pcache = []
        for i in range(P):
            p = block[i]
            c = pcache[i]
            kind = cfg.layer_kind(i)
            if kind == "rwkv":
                h = rms_norm(x, p["ln1"], cfg.norm_eps)
                o, st = rwkv_mod.rwkv_time_mix(
                    p["tmix"], h, cfg, state={"s": c["s"], "last": c["last"]})
                x = x + o
                h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
                o, clast = rwkv_mod.rwkv_channel_mix(p["tmix"], h2, state=c["clast"])
                x = x + o
                new_pcache.append({"s": st["s"], "last": st["last"], "clast": clast})
            elif kind == "ssm":
                h = rms_norm(x, p["ln1"], cfg.norm_eps)
                o, st = ssm_mod.ssm_apply(p["ssm"], h, cfg, state=c)
                x = x + o
                x, _ = _ffn_block(p, x, cfg)
                new_pcache.append(st)
            else:
                x, nc = _decode_attn_layer(p, x, cfg, attn_kind(cfg, i), c, pos)
                if kind == "cross":
                    x = _cross_block(p, x, cfg, memory)
                x, _ = _ffn_block(p, x, cfg)
                new_pcache.append(nc)
        return x, tuple(new_pcache)

    x, new_cache = jax.lax.scan(period_body, x, (params["blocks"], cache),
                                unroll=scan_unroll())
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params.get("lm_head", None)
    logits = (x @ head) if head is not None else (x @ params["embed"].T)
    return softcap(logits.astype(jnp.float32), cfg.final_softcap), new_cache
