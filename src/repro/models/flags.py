"""Analysis-mode flags.

XLA's HloCostAnalysis counts a ``while`` body ONCE regardless of trip count,
so cost_analysis() on a scanned layer stack undercounts flops/bytes and the
HLO text shows loop-body collectives once. For the roofline pass, dryrun
lowers two shallow variants (depth P and 2P) with every scan *unrolled*
(``analysis_mode``) and extrapolates the per-period body:
``total = f(P) + (n_periods - 1) * (f(2P) - f(P))``.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

_ctx = threading.local()


def analysis_mode() -> bool:
    return getattr(_ctx, "analysis", False)


@contextmanager
def analysis(enabled: bool = True):
    old = analysis_mode()
    _ctx.analysis = enabled
    try:
        yield
    finally:
        _ctx.analysis = old


def scan_unroll() -> bool:
    """unroll= argument for lax.scan: full unroll in analysis mode."""
    return True if analysis_mode() else 1


def analysis_chunk(default: int, total: int, max_trips: int = 16) -> int:
    """Chunk size: in analysis mode bound the unrolled trip count."""
    if not analysis_mode():
        return default
    return max(default, -(-total // max_trips))
