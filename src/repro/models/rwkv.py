"""RWKV6 ("Finch") block: linear attention with data-dependent per-channel
decay [arXiv:2404.05892].

Recurrence per head (k-dim K, v-dim V):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(decay(x_t))) in (0,1)^K, data-dependent via a LoRA.

Evaluated chunk-parallel (the standard chunked-WKV form): ``lax.scan`` over
time chunks carrying S, intra-chunk contributions via a strictly-lower-
triangular decay-weighted matmul. fp32 internals; chunk kept small (64) so the
cumulative-decay ratios stay well-conditioned. The Pallas kernel in
``repro.kernels.rwkv6_chunk`` implements the same chunk step for TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.flags import analysis_chunk, scan_unroll
from repro.models.layers import dtype_of, init_dense, rms_norm


def _dims(cfg: ModelConfig):
    hd = cfg.rwkv.head_dim
    nh = cfg.d_model // hd
    return nh, hd


def rwkv_init(key, cfg: ModelConfig):
    d = cfg.d_model
    nh, hd = _dims(cfg)
    lora = cfg.rwkv.decay_lora
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 12)
    return {
        # time-mix (attention analogue)
        "mix_r": jnp.full((d,), 0.5, dt),
        "mix_k": jnp.full((d,), 0.5, dt),
        "mix_v": jnp.full((d,), 0.5, dt),
        "mix_w": jnp.full((d,), 0.5, dt),
        "mix_g": jnp.full((d,), 0.5, dt),
        "wr": init_dense(ks[0], d, d, dt),
        "wk": init_dense(ks[1], d, d, dt),
        "wv": init_dense(ks[2], d, d, dt),
        "wg": init_dense(ks[3], d, d, dt),
        "wo": init_dense(ks[4], d, d, dt),
        "decay_a": init_dense(ks[5], d, lora, dt),
        "decay_b": init_dense(ks[6], lora, d, dt),
        "decay_base": jnp.full((d,), -4.0, jnp.float32),
        "bonus_u": (jax.random.normal(ks[7], (nh, hd), jnp.float32) * 0.1),
        "ln_x": jnp.zeros((d,), jnp.float32),
        # channel-mix (FFN analogue)
        "cmix_r": jnp.full((d,), 0.5, dt),
        "cmix_k": jnp.full((d,), 0.5, dt),
        "ck": init_dense(ks[8], d, cfg.d_ff, dt),
        "cv": init_dense(ks[9], cfg.d_ff, d, dt),
        "cr": init_dense(ks[10], d, d, dt),
    }


def _token_shift(x, mix, last=None):
    """x [B,T,D]; returns lerp(x_{t-1}, x_t, mix). last: [B,1,D] carry or None."""
    if last is None:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    else:
        prev = jnp.concatenate([last, x[:, :-1]], axis=1)
    return x + (prev - x) * (1.0 - mix)


def _wkv_chunk_scan(r, k, v, w, u, s0, chunk=64):
    """r,k,v,w: [B, T, H, D] (w in (0,1)); u: [H, D]; s0: [B, H, D, D].

    Returns (o [B,T,H,D], s_T). fp32 throughout.
    """
    b, t, h, d = r.shape
    # analysis mode caps unrolled trips at 32: the WKV loop is <=5% of
    # RWKV6 flops (projections dominate), so the mild intra-chunk flop
    # inflation from a larger analysis chunk is noise (see EXPERIMENTS.md).
    chunk = min(analysis_chunk(chunk, t, max_trips=32), t)
    nc = -(-t // chunk)
    pad = nc * chunk - t
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, z)
        k = jnp.pad(k, z)
        v = jnp.pad(v, z)
        w = jnp.pad(w, z, constant_values=1.0)

    def to_chunks(x):
        return x.reshape(b, nc, chunk, h, d).transpose(1, 0, 3, 2, 4)  # [N,B,H,C,D]

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), k=-1)  # strict lower

    def step(s, xs):
        rb, kb, vb, wb = xs  # [B,H,C,D]
        logw = jnp.log(jnp.maximum(wb, 1e-12))
        q_inc = jnp.cumsum(logw, axis=2)                    # log prod_{<=t}
        q_exc = q_inc - logw                                # log prod_{<t}
        # inter-chunk: o_t += (r_t * prod_{<t} w) @ S
        r_dec = rb * jnp.exp(q_exc)
        o = jnp.einsum("bhtd,bhde->bhte", r_dec, s)
        # intra-chunk: scores[t,s] = sum_d r_t[d] k_s[d] exp(q_exc[t]-q_inc[s])
        r_s = rb * jnp.exp(q_exc)
        k_s = kb * jnp.exp(-q_inc)
        scores = jnp.einsum("bhtd,bhsd->bhts", r_s, k_s) * tri
        o = o + jnp.einsum("bhts,bhse->bhte", scores, vb)
        # current-token bonus
        cur = jnp.sum(rb * u[None, :, None, :] * kb, axis=-1, keepdims=True)
        o = o + cur * vb
        # state update: S' = diag(prod w) S + sum_s diag(prod_{>s} w) k_s v_s
        total = q_inc[:, :, -1:, :]                          # [B,H,1,D]
        k_dec = kb * jnp.exp(total - q_inc)
        s_new = jnp.exp(total[:, :, 0, :, None]) * s + jnp.einsum(
            "bhsd,bhse->bhde", k_dec, vb)
        return s_new, o

    step = jax.checkpoint(step, prevent_cse=False)
    s_t, oc = jax.lax.scan(step, s0, (rc, kc, vc, wc), unroll=scan_unroll())
    o = oc.transpose(1, 0, 3, 2, 4).reshape(b, nc * chunk, h, d)
    return o[:, :t], s_t


def rwkv_time_mix(p, x, cfg: ModelConfig, state=None):
    """x [B,T,D]. state: None or {'s': [B,H,D,D], 'last': [B,1,D]}."""
    b, t, d = x.shape
    nh, hd = _dims(cfg)
    last = state["last"] if state is not None else None
    xr = _token_shift(x, p["mix_r"], last)
    xk = _token_shift(x, p["mix_k"], last)
    xv = _token_shift(x, p["mix_v"], last)
    xw = _token_shift(x, p["mix_w"], last)
    xg = _token_shift(x, p["mix_g"], last)

    r = (xr @ p["wr"]).reshape(b, t, nh, hd).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, t, nh, hd).astype(jnp.float32)
    v = (xv @ p["wv"]).reshape(b, t, nh, hd).astype(jnp.float32)
    g = jax.nn.silu((xg @ p["wg"]).astype(jnp.float32))
    decay = p["decay_base"] + (jnp.tanh((xw @ p["decay_a"]).astype(jnp.float32))
                               @ p["decay_b"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(decay)).reshape(b, t, nh, hd)  # in (0,1)

    s0 = state["s"] if state is not None else jnp.zeros((b, nh, hd, hd), jnp.float32)
    o, s_t = _wkv_chunk_scan(r, k, v, w, p["bonus_u"], s0)
    o = o.reshape(b, t, d)
    o = rms_norm(o, p["ln_x"], eps=1e-5) * g
    out = o.astype(x.dtype) @ p["wo"]
    new_state = {"s": s_t, "last": x[:, -1:]}
    return out, new_state


def rwkv_channel_mix(p, x, state=None):
    last = state if state is not None else None
    xk = _token_shift(x, p["cmix_k"], last)
    xr = _token_shift(x, p["cmix_r"], last)
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    kv = k @ p["cv"]
    return jax.nn.sigmoid((xr @ p["cr"]).astype(jnp.float32)).astype(x.dtype) * kv, x[:, -1:]
