"""Chunked (online-softmax) attention in pure jnp.

No ``L x L`` score tensor is ever materialized: training/prefill scans over KV
chunks carrying the running (max, denominator, accumulator) triple — the flash
attention recurrence expressed at the XLA level. This is what makes 32k
prefill and 500k decode lowerable within HBM; the Pallas kernel in
``repro.kernels.flash_attention`` implements the same recurrence with explicit
VMEM BlockSpecs for TPU and is validated against this reference.

Supported masks: causal full, sliding-window (swa), block-local (chunked),
and per-layer local/global alternation (gemma2). Logit softcap supported.
GQA via kv-head broadcast.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.flags import analysis_chunk, scan_unroll

NEG_INF = -1e30


def _repeat_kv(k, n_rep):
    if n_rep == 1:
        return k
    b, s, kvh, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kvh, n_rep, d)).reshape(b, s, kvh * n_rep, d)


def _mask_chunk(q_pos, k_pos, kind, window):
    """[Tq, Tk] boolean allow-mask for query positions vs key positions."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if kind == "full":
        return causal
    if kind == "swa":
        return causal & (q_pos[:, None] - k_pos[None, :] < window)
    if kind == "chunked":
        return causal & (q_pos[:, None] // window == k_pos[None, :] // window)
    raise ValueError(kind)


def attention(q, k, v, *, kind="full", window=4096, logit_softcap=0.0,
              chunk=1024, q_offset=0, backend=None):
    """Causal multi-head attention, backend-dispatched.

    q: [B, Tq, H, D];  k, v: [B, Tk, KV, D];  returns [B, Tq, H, D].
    ``q_offset``: absolute position of q[0] (Tk = q_offset + Tq for training).

    Execution routes through ``repro.kernels.dispatch.attention``
    (``backend`` arg > ``REPRO_KERNEL_BACKEND`` env > platform default):
    the Pallas flash kernel on tpu/gpu, this module's chunked reference on
    CPU (``"xla"`` — on CPU the resolved program is exactly
    :func:`attention_ref`). Shapes the kernel doesn't cover fall back to
    the reference regardless of backend.
    """
    from repro.kernels.dispatch import attention as dispatch_attention

    return dispatch_attention(q, k, v, kind=kind, window=window,
                              logit_softcap=logit_softcap, chunk=chunk,
                              q_offset=q_offset, backend=backend)


def attention_ref(q, k, v, *, kind="full", window=4096, logit_softcap=0.0,
                  chunk=1024, q_offset=0):
    """The pure-XLA chunked (online-softmax) reference implementation."""
    b, tq, h, d = q.shape
    tk = k.shape[1]
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    scale = d ** -0.5
    qf = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3)  # [B,H,Tq,D]
    q_pos = q_offset + jnp.arange(tq)

    chunk = min(analysis_chunk(chunk, tk), tk)
    n_chunks = -(-tk // chunk)
    pad = n_chunks * chunk - tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # [N, B, H, C, D]
    kc = k.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 3, 2, 4)

    def body(carry, xs):
        m, l, acc, idx = carry
        kb, vb = xs
        k_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb.astype(jnp.float32))
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        allow = _mask_chunk(q_pos, k_pos, kind, window) & (k_pos < tk)[None, :]
        s = jnp.where(allow[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((b, h, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, d), jnp.float32)
    # flash-training memory: backward recomputes per-chunk probabilities
    # instead of saving the stacked [B,H,Tq,Tk] scores.
    body = jax.checkpoint(body, prevent_cse=False)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)), (kc, vc),
                                     unroll=scan_unroll())
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def cross_attention(q, k, v, *, q_chunk=512):
    """Non-causal attention against fixed memory (image / encoder tokens).
    Chunked over queries so scores stay [B, H, q_chunk, Tk]."""
    b, tq, h, d = q.shape
    n_rep = h // k.shape[2]
    kf = _repeat_kv(k, n_rep).astype(jnp.float32)
    vf = _repeat_kv(v, n_rep).astype(jnp.float32)

    def one_chunk(qc):
        s = jnp.einsum("bqhd,bkhd->bhqk", qc.astype(jnp.float32) * d ** -0.5, kf)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, vf).astype(q.dtype)

    if tq <= q_chunk:
        return one_chunk(q)
    n = -(-tq // q_chunk)
    pad = n * q_chunk - tq
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
    qc = qp.reshape(b, n, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    _, out = jax.lax.scan(lambda c, x: (c, one_chunk(x)), None, qc,
                          unroll=scan_unroll())
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, n * q_chunk, h, d)
    return out[:, :tq]


def decode_attention(q, k_cache, v_cache, cache_len, *, kind="full",
                     window=4096, logit_softcap=0.0, chunk=8192):
    """Single-token decode: q [B, 1, H, D], cache [B, S, KV, D].

    Convention: the new token's k/v have already been written into the cache,
    and ``cache_len`` counts them (the query position is ``cache_len - 1``).
    For windowed kinds only the trailing ``window`` cache positions are
    attended (sliced), bounding work for 500k contexts; full attention scans
    the entire cache in chunks with an online softmax.
    """
    b, _, h, d = q.shape
    s_max = k_cache.shape[1]
    if kind in ("swa", "chunked"):
        w = min(window, s_max)
        start = jnp.clip(cache_len - w, 0, s_max - w)
        k_cache = jax.lax.dynamic_slice_in_dim(k_cache, start, w, axis=1)
        v_cache = jax.lax.dynamic_slice_in_dim(v_cache, start, w, axis=1)
        pos = start + jnp.arange(w)
        if kind == "chunked":
            valid = (pos < cache_len) & (pos // window == jnp.maximum(cache_len - 1, 0) // window)
        else:
            valid = (pos < cache_len) & (cache_len - 1 - pos < window)
    else:
        pos = jnp.arange(s_max)
        valid = pos < cache_len

    n_rep = h // k_cache.shape[2]
    kf = _repeat_kv(k_cache, n_rep)
    vf = _repeat_kv(v_cache, n_rep)
    tk = kf.shape[1]
    chunk = min(analysis_chunk(chunk, tk), tk)
    n_chunks = -(-tk // chunk)
    pad = n_chunks * chunk - tk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        valid = jnp.pad(valid, (0, pad))
    kc = kf.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 3, 2, 4)
    vc = vf.reshape(b, n_chunks, chunk, h, d).transpose(1, 0, 3, 2, 4)
    validc = valid.reshape(n_chunks, chunk)
    qf = (q[:, 0] * d ** -0.5).astype(jnp.float32)  # [B, H, D]

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, ok = xs
        s = jnp.einsum("bhd,bhkd->bhk", qf, kb.astype(jnp.float32))
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        s = jnp.where(ok[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhk,bhkd->bhd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    a0 = jnp.zeros((b, h, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, validc),
                                  unroll=scan_unroll())
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out[:, None].astype(q.dtype)  # [B, 1, H, D]
