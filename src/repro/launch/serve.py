"""Serving launcher: batched greedy decoding with the KV/SSM/RWKV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduced
    from repro.models.model import decode_step, init_params, make_cache

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    print(f"serving {cfg.name} ({cfg.family}), batch={args.batch}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    max_len = args.prompt_len + args.gen
    cache = make_cache(cfg, args.batch, max_len)
    memory = None
    if cfg.family == "vlm":
        memory = 0.1 * jnp.ones((args.batch, cfg.num_image_tokens, cfg.d_model))
    elif cfg.family == "audio":
        memory = 0.1 * jnp.ones((args.batch, cfg.num_audio_frames, cfg.d_model))

    step = jax.jit(lambda tok, c, pos: decode_step(
        params, cfg, tok, c, pos, memory=memory))

    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab_size)
    # prefill via sequential decode (cache-consistent for every family)
    t0 = time.time()
    tok = prompts[:, :1]
    for i in range(args.prompt_len):
        logits, cache = step(prompts[:, i:i + 1], cache, jnp.int32(i))
    generated = []
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    for i in range(args.gen):
        generated.append(tok)
        logits, cache = step(tok, cache, jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = jnp.concatenate(generated, 1)
    dt = time.time() - t0
    total_tokens = args.batch * (args.prompt_len + args.gen)
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. prefill)")
    print("sample token ids:", list(map(int, out[0][:12])))


if __name__ == "__main__":
    main()
