import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x input shape) on
the production meshes, print memory/cost analysis, and dump roofline terms.

Roofline methodology: XLA's HloCostAnalysis counts ``while`` (lax.scan)
bodies ONCE regardless of trip count, so the deep scanned stacks would be
undercounted. We therefore compile THREE programs per pair:
  1. the full config (scanned)        -> compile proof + memory_analysis;
  2. depth = 1 period, scans unrolled -> f1 (per-device flops/bytes/colls);
  3. depth = 2 periods, unrolled      -> f2;
and extrapolate  total = f1 + (n_periods - 1) * (f2 - f1)
(periods are structurally identical, so f2 - f1 is exactly one period body).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --out experiments/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, INPUT_SHAPES, applicable_shapes, get_config
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.launch.roofline import Roofline, collective_stats, model_flops_for
from repro.launch.steps import (
    make_prefill_step,
    make_serve_step,
    make_train_step,
    prefill_input_specs,
    serve_input_specs,
    serve_shardings,
    train_input_specs,
    train_shardings,
)
from repro.models import flags
from repro.models.model import period_length
from repro.sharding.specs import activation_sharding, infer_pytree_specs, set_mesh


def _act_spec(mode_flag, mesh, train=True):
    dp = ("data",) if train else dp_axes(mesh)
    if mode_flag == "seq":
        return P(dp if not train else "data", "model", None)
    if mode_flag == "dmodel":
        return P(dp if not train else "data", None, "model")
    return None  # batch-only


def _compile_step(cfg, shape, mesh, *, algorithm, seq_parallel, tp2d=False):
    """Lower + compile one program; returns the compiled object.
    ``seq_parallel``: True/"seq" | False/None (batch-only) | "dmodel".
    ``tp2d``: decode-only 2D tensor-parallel weight sharding (H4)."""
    if seq_parallel is True:
        seq_parallel = "seq"
    if shape.mode == "train":
        state, batches = train_input_specs(cfg, shape, mesh)
        st_specs, b_specs = train_shardings(state, batches, mesh)
        step = make_train_step(cfg, mesh, algorithm=algorithm)
        act = _act_spec(seq_parallel, mesh) if seq_parallel else None
        with activation_sharding(act):
            lowered = jax.jit(step, in_shardings=(st_specs, b_specs),
                              out_shardings=(st_specs, None)).lower(state, batches)
    elif shape.mode == "prefill":
        params, tokens, memory = prefill_input_specs(cfg, shape, mesh)
        p_specs = infer_pytree_specs(params, mesh)
        dp = dp_axes(mesh)
        tok_spec = NamedSharding(mesh, P(dp, None))
        args = (params, tokens) + ((memory,) if memory is not None else ())
        in_sh = (p_specs, tok_spec) + (
            (NamedSharding(mesh, P(dp, None, None)),) if memory is not None else ())
        step = make_prefill_step(cfg)
        act = (P(dp, "model", None) if seq_parallel in (True, "seq")
               else P(dp, None, "model") if seq_parallel == "dmodel" else None)
        with activation_sharding(act):
            lowered = jax.jit(step, in_shardings=in_sh,
                              out_shardings=None).lower(*args)
    else:  # decode
        params, cache, token, pos, memory = serve_input_specs(cfg, shape, mesh)
        p_specs, c_specs, tok_spec = serve_shardings(
            params, cache, mesh, shape.global_batch, tp2d=tp2d)
        pos_spec = NamedSharding(mesh, P())
        args = (params, cache, token, pos) + ((memory,) if memory is not None else ())
        in_sh = (p_specs, c_specs, tok_spec, pos_spec) + (
            (NamedSharding(mesh, P(None, None, None)),) if memory is not None else ())
        step = make_serve_step(cfg)
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=None).lower(*args)
    return lowered.compile()


def _metrics(compiled):
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": dict(coll.bytes_by_kind),
        "coll_count": dict(coll.count_by_kind),
    }


def _depth_variant(cfg, k: int):
    """Config with k periods of depth (and k encoder layers for audio)."""
    P_ = period_length(cfg)
    kw = {"num_layers": k * P_}
    if cfg.encoder_layers:
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def _extrapolate(f1, f2, n):
    out = {"flops": f1["flops"] + (n - 1) * (f2["flops"] - f1["flops"]),
           "bytes": f1["bytes"] + (n - 1) * (f2["bytes"] - f1["bytes"])}
    kinds = set(f1["coll_bytes"]) | set(f2["coll_bytes"])
    cb, cc = {}, {}
    for k in kinds:
        b1 = f1["coll_bytes"].get(k, 0)
        b2 = f2["coll_bytes"].get(k, 0)
        cb[k] = max(0, b1 + (n - 1) * (b2 - b1))
        c1 = f1["coll_count"].get(k, 0)
        c2 = f2["coll_count"].get(k, 0)
        cc[k] = max(0, c1 + (n - 1) * (c2 - c1))
    out["coll_bytes"] = cb
    out["coll_count"] = cc
    return out


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, algorithm: str = "fedpbc",
               dispatch: str = None, seq_parallel: bool = True,
               analyze: bool = True, tp2d: bool = False):
    cfg = get_config(arch)
    if dispatch and cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=dispatch))
    shape = INPUT_SHAPES[shape_name]
    if shape.name not in [s.name for s in applicable_shapes(cfg)]:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "mesh": "2x16x16" if multi_pod else "16x16",
                "reason": "full-attention arch at 500k / enc-dec long decode"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_mesh(mesh)
    t0 = time.time()
    try:
        with mesh:
            compiled = _compile_step(cfg, shape, mesh, algorithm=algorithm,
                                     seq_parallel=seq_parallel, tp2d=tp2d)
            t_full = time.time() - t0
            if analyze:
                n_periods = cfg.num_layers // period_length(cfg)
                with flags.analysis():
                    c1 = _compile_step(_depth_variant(cfg, 1), shape, mesh,
                                       algorithm=algorithm,
                                       seq_parallel=seq_parallel, tp2d=tp2d)
                    f1 = _metrics(c1)
                    del c1
                    c2 = _compile_step(_depth_variant(cfg, 2), shape, mesh,
                                       algorithm=algorithm,
                                       seq_parallel=seq_parallel, tp2d=tp2d)
                    f2 = _metrics(c2)
                    del c2
                est = _extrapolate(f1, f2, n_periods)
            else:
                est = _metrics(compiled)
    except Exception as e:
        set_mesh(None)
        return {"arch": arch, "shape": shape_name, "status": "FAIL",
                "mesh": "2x16x16" if multi_pod else "16x16",
                "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2500:]}
    set_mesh(None)

    mem = compiled.memory_analysis()
    chips = 512 if multi_pod else 256
    rf = Roofline(
        flops=est["flops"],
        hbm_bytes=est["bytes"],
        coll_bytes=float(sum(est["coll_bytes"].values())),
        chips=chips,
        model_flops=model_flops_for(cfg, shape, mode=shape.mode),
    )
    result = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
        "compile_s": round(time.time() - t0, 1),
        "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "collectives": {k: [est["coll_count"][k], est["coll_bytes"][k]]
                        for k in est["coll_bytes"]},
        **rf.row(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} mesh={result['mesh']} ==")
        print("memory_analysis:", mem)
        print("cost_analysis (extrapolated): flops=%.3e bytes=%.3e"
              % (rf.flops, rf.hbm_bytes))
        print("collectives:", result["collectives"])
        print("roofline: compute=%.4fs memory=%.4fs collective=%.4fs -> %s"
              % (rf.t_compute, rf.t_memory, rf.t_collective, rf.bottleneck))
        print("useful fraction (model/HLO flops): %.3f" % rf.useful_fraction)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--algorithm", default="fedpbc")
    ap.add_argument("--dispatch", default=None, help="override MoE dispatch")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--act-spec", default=None, choices=["seq", "dmodel"])
    ap.add_argument("--tp2d", action="store_true",
                    help="decode: 2D tensor-parallel weights (H4)")
    ap.add_argument("--no-analyze", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        for a in archs:
            for s in shapes:
                sp = args.act_spec or (not args.no_seq_parallel)
                r = lower_pair(a, s, multi_pod=mp, algorithm=args.algorithm,
                               dispatch=args.dispatch,
                               seq_parallel=sp,
                               analyze=not args.no_analyze, tp2d=args.tp2d)
                print(json.dumps({k: v for k, v in r.items() if k != "trace"}),
                      flush=True)
                if r["status"] == "FAIL":
                    print(r.get("trace", ""), flush=True)
                results.append(r)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"DONE ok={n_ok} skip={n_skip} fail={n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
