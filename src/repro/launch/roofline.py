"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak_FLOPs)
memory term     = HLO_bytes / (chips * HBM_bw)
collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / bytes from ``compiled.cost_analysis()``; collective bytes are NOT
in cost_analysis — we parse the (SPMD-partitioned) HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the HLO text.

    (Output bytes ~ operand bytes for AG/AR/A2A up to the sharding factor;
    this is the standard per-device traffic proxy.)"""
    st = CollectiveStats()
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + b
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
    return st


@dataclass
class Roofline:
    """cost_analysis() and the HLO text describe the PER-DEVICE program
    (calibrated: a [4096^2] matmul sharded 4-way reports 1/4 the flops, and
    dots count 2 flops/MAC), so the three terms are per-chip seconds and the
    'chips x peak' denominators of the assignment reduce to per-chip rates."""

    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    chips: int
    model_flops: float = 0.0     # 6*N*D useful flops (global)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
        }


def model_flops_for(cfg, shape, *, mode: str) -> float:
    """MODEL_FLOPS = 6 * N_active * D_tokens (per the assignment), where
    decode counts one token per sequence."""
    n = cfg.active_param_count()
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    tokens = shape.global_batch           # one new token per sequence
    return 2.0 * n * tokens
