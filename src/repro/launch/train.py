"""Production federated-training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --rounds 50 --clients 8 --algorithm fedpbc --scheme bernoulli

Runs the FedPBC round engine over the selected architecture on the local
devices (reduced configs on CPU; full configs are exercised via dryrun.py).

Rounds execute on the scanned engine (``repro.core.make_run_rounds``): token
batches are sampled on device by ``repro.data.lm_source`` and every
log/checkpoint interval runs as ONE dispatch (``jax.lax.scan`` over the round
function), instead of one dispatch + host batch upload per round.
Checkpoints carry the full ``{fed, ds}`` state every --ckpt-every rounds, so
a restore resumes mid-sweep with the identical trajectory.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--algorithm", default="fedpbc")
    ap.add_argument("--scheme", default="bernoulli",
                    choices=["bernoulli", "markov", "cyclic"])
    ap.add_argument("--time-varying", action="store_true")
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpointing import latest_step, restore, save
    from repro.configs import FederationConfig, get_config, reduced
    from repro.core import (
        build_base_probs,
        init_fed_state,
        make_algorithm,
        make_link_process,
        make_run_rounds,
    )
    from repro.data import lm_source
    from repro.models.model import init_params, loss_fn
    from repro.optim import paper_decay, sgd

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    print(f"arch={cfg.name} family={cfg.family} params~"
          f"{cfg.param_count() / 1e6:.1f}M reduced={args.reduced}")

    m = args.clients
    fed = FederationConfig(algorithm=args.algorithm, num_clients=m,
                           local_steps=args.local_steps, scheme=args.scheme,
                           time_varying=args.time_varying)
    p, _, _ = build_base_probs(jax.random.PRNGKey(args.seed), m, 10,
                               alpha=0.1, sigma0=4.0, delta=0.05)
    print("client uplink probabilities:", np.asarray(p).round(3))
    algo = make_algorithm(fed)
    link = make_link_process(jnp.asarray(p), fed)
    opt = sgd(paper_decay(args.lr))

    def loss(params, batch):
        return loss_fn(params, cfg, batch, remat=False)

    if cfg.family == "vlm":
        memory_shape = (args.batch, cfg.num_image_tokens, cfg.d_model)
    elif cfg.family == "audio":
        memory_shape = (args.batch, cfg.num_audio_frames, cfg.d_model)
    else:
        memory_shape = None
    source = lm_source(num_clients=m, local_steps=args.local_steps,
                       batch=args.batch, seq=args.seq, vocab=cfg.vocab_size,
                       memory_shape=memory_shape)

    run_rounds = make_run_rounds(loss, opt, algo, link, fed, source)
    params = init_params(jax.random.PRNGKey(args.seed + 1), cfg)
    st = init_fed_state(jax.random.PRNGKey(args.seed + 2), params, fed,
                        algo, link, opt)
    ds_state = source.init(jax.random.PRNGKey(args.seed + 3))
    data_key = jax.random.PRNGKey(args.seed + 4)

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            try:
                st, ds_state = restore(args.ckpt_dir, last, (st, ds_state))
            except (KeyError, AssertionError) as e:
                raise SystemExit(
                    f"checkpoint {args.ckpt_dir}/ckpt_{last:08d}.npz does not "
                    "match the current (FedState, ds_state) layout — likely a "
                    "pre-scan-engine checkpoint (FedState only) or a different "
                    f"--arch/--clients setting. Delete or move --ckpt-dir to "
                    f"start fresh. ({e})")
            print(f"restored round {int(st.round)} from {args.ckpt_dir}")

    def next_boundary(t: int) -> int:
        """Next log or checkpoint boundary after round t (scan chunk end)."""
        nxt = min(t - t % args.log_every + args.log_every, args.rounds)
        if args.ckpt_dir:
            nxt = min(nxt, t - t % args.ckpt_every + args.ckpt_every)
        return nxt

    t0 = time.time()
    start_round = t = int(st.round)
    while t < args.rounds:
        chunk = next_boundary(t) - t
        st, ds_state, mets = run_rounds(st, ds_state, data_key, chunk)
        t += chunk
        print(f"round {t:4d} loss {float(mets['loss'][-1]):.4f} "
              f"active {int(mets['num_active'][-1])}/{m} "
              f"mean_staleness {float(np.mean(mets['staleness'][-1])):.1f} "
              f"({(time.time() - t0):.1f}s)", flush=True)
        if args.ckpt_dir and t % args.ckpt_every == 0:
            save(args.ckpt_dir, t, (st, ds_state))
    print(f"done: {args.rounds - start_round} rounds in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
