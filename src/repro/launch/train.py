"""Production federated-training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
      --rounds 50 --clients 8 --algorithm fedpbc --scheme bernoulli

Runs the FedPBC round engine over the selected architecture on the local
devices (reduced configs on CPU; full configs are exercised via dryrun.py).
Checkpoints the FedState every --ckpt-every rounds.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--algorithm", default="fedpbc")
    ap.add_argument("--scheme", default="bernoulli",
                    choices=["bernoulli", "markov", "cyclic"])
    ap.add_argument("--time-varying", action="store_true")
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.checkpointing import latest_step, restore, save
    from repro.configs import FederationConfig, get_config, reduced
    from repro.core import (
        build_base_probs,
        init_fed_state,
        make_algorithm,
        make_link_process,
        make_round_fn,
    )
    from repro.data import federated_lm_batches
    from repro.models.model import init_params, loss_fn
    from repro.optim import paper_decay, sgd

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    print(f"arch={cfg.name} family={cfg.family} params~"
          f"{cfg.param_count() / 1e6:.1f}M reduced={args.reduced}")

    m = args.clients
    fed = FederationConfig(algorithm=args.algorithm, num_clients=m,
                           local_steps=args.local_steps, scheme=args.scheme,
                           time_varying=args.time_varying)
    p, _, _ = build_base_probs(jax.random.PRNGKey(args.seed), m, 10,
                               alpha=0.1, sigma0=4.0, delta=0.05)
    print("client uplink probabilities:", np.asarray(p).round(3))
    algo = make_algorithm(fed)
    link = make_link_process(jnp.asarray(p), fed)
    opt = sgd(paper_decay(args.lr))

    def loss(params, batch):
        return loss_fn(params, cfg, batch, remat=False)

    rf = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    params = init_params(jax.random.PRNGKey(args.seed + 1), cfg)
    st = init_fed_state(jax.random.PRNGKey(args.seed + 2), params, fed,
                        algo, link, opt)

    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            st = restore(args.ckpt_dir, last, st)
            print(f"restored round {int(st.round)} from {args.ckpt_dir}")

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    start_round = int(st.round)
    for t in range(start_round, args.rounds):
        b = federated_lm_batches(rng, num_clients=m,
                                 local_steps=args.local_steps,
                                 batch=args.batch, seq=args.seq,
                                 vocab=cfg.vocab_size)
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "labels": jnp.asarray(b["labels"])}
        if cfg.family == "vlm":
            batch["memory"] = 0.1 * jnp.ones(
                (m, args.local_steps, args.batch, cfg.num_image_tokens, cfg.d_model))
        elif cfg.family == "audio":
            batch["memory"] = 0.1 * jnp.ones(
                (m, args.local_steps, args.batch, cfg.num_audio_frames, cfg.d_model))
        st, mets = rf(st, batch)
        if (t + 1) % 10 == 0 or t == start_round:
            print(f"round {t + 1:4d} loss {float(mets['loss']):.4f} "
                  f"active {int(mets['num_active'])}/{m} "
                  f"mean_staleness {float(np.mean(mets['staleness'])):.1f} "
                  f"({(time.time() - t0):.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, t + 1, st)
    print(f"done: {args.rounds - start_round} rounds in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
