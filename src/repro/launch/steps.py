"""Step builders + ShapeDtypeStruct input specs for dry-run / train / serve.

- ``train_step``: one full FedPBC round (Alg. 1) at datacenter scale in the
  ``pod_silo`` placement — each pod is one federated client; the masked
  aggregation + postponed broadcast lower to cross-pod collectives.
- ``prefill_step``: full-sequence forward (inference prefill).
- ``serve_step``: one-token decode against the KV/SSM/RWKV cache + greedy
  sampling.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FederationConfig, ModelConfig, ShapeConfig
from repro.core.algorithms import make_algorithm
from repro.core.connectivity import make_link_process
from repro.core.federated import FedState, init_fed_state, make_round_fn
from repro.launch.mesh import dp_axes, num_clients_for
from repro.models.model import (
    decode_step,
    forward,
    init_params,
    loss_fn,
    make_cache,
)
from repro.optim import sgd
from repro.sharding.specs import infer_pytree_specs, spec_for_shape

MEM_DTYPE = jnp.bfloat16


def _memory_shape(cfg: ModelConfig, batch: int):
    if cfg.family == "vlm":
        return (batch, cfg.num_image_tokens, cfg.d_model)
    if cfg.family == "audio":
        return (batch, cfg.num_audio_frames, cfg.d_model)
    return None


# ---------------------------------------------------------------------------
# Train (federated round)
# ---------------------------------------------------------------------------


def make_fed_setup(cfg: ModelConfig, mesh: Mesh, *, local_steps: int = 1,
                   algorithm: str = "fedpbc"):
    m = num_clients_for(mesh)
    fed = FederationConfig(algorithm=algorithm, num_clients=m,
                           local_steps=local_steps, scheme="bernoulli",
                           placement="pod_silo")
    algo = make_algorithm(fed)
    p_base = jnp.full((m,), 0.8)
    link = make_link_process(p_base, fed)
    opt = sgd(1e-3, momentum=0.9)

    def _loss(params, batch):
        return loss_fn(params, cfg, batch)

    spmd = "pod" if ("pod" in mesh.axis_names and m > 1) else None
    round_fn = make_round_fn(_loss, opt, algo, link, fed, spmd_axis_name=spmd)
    return fed, algo, link, opt, round_fn


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      *, local_steps: int = 1):
    """ShapeDtypeStructs for (FedState, batches) of one federated round."""
    m = num_clients_for(mesh)
    b_client = shape.global_batch // m
    fed, algo, link, opt, _ = make_fed_setup(cfg, mesh, local_steps=local_steps)

    def make_state():
        params = init_params(jax.random.PRNGKey(0), cfg)
        return init_fed_state(jax.random.PRNGKey(1), params, fed, algo, link, opt)

    state = jax.eval_shape(make_state)
    batches = {
        "tokens": jax.ShapeDtypeStruct((m, local_steps, b_client, shape.seq_len), jnp.int32),
        "labels": jax.ShapeDtypeStruct((m, local_steps, b_client, shape.seq_len), jnp.int32),
    }
    ms = _memory_shape(cfg, b_client)
    if ms:
        batches["memory"] = jax.ShapeDtypeStruct((m, local_steps) + ms, MEM_DTYPE)
    return state, batches


def _batch_spec(shape, mesh):
    """[m, s, B, ...]: client axis over 'pod', batch over 'data'."""
    dp = "data"
    spec = [None] * len(shape)
    if "pod" in mesh.axis_names and shape[0] % mesh.shape["pod"] == 0:
        spec[0] = "pod"
    if len(shape) >= 3 and shape[2] % mesh.shape[dp] == 0:
        spec[2] = dp
    return P(*spec)


def train_shardings(state, batches, mesh: Mesh):
    client_leaves = ("clients", "opt_state", "algo_state", "last_active")

    def state_specs(s: FedState):
        return FedState(
            server=infer_pytree_specs(s.server, mesh),
            clients=infer_pytree_specs(s.clients, mesh, client_axis=True),
            opt_state=infer_pytree_specs(s.opt_state, mesh, client_axis=True),
            algo_state=infer_pytree_specs(s.algo_state, mesh, client_axis=True),
            link_state=jax.tree.map(
                lambda x: NamedSharding(mesh, P()), s.link_state),
            round=NamedSharding(mesh, P()),
            key=NamedSharding(mesh, P()),
            last_active=NamedSharding(mesh, P()),
        )

    st_specs = state_specs(state)
    b_specs = jax.tree.map(
        lambda x: NamedSharding(mesh, _batch_spec(x.shape, mesh)), batches)
    return st_specs, b_specs


def make_train_step(cfg: ModelConfig, mesh: Mesh, *, local_steps: int = 1,
                    algorithm: str = "fedpbc"):
    _, _, _, _, round_fn = make_fed_setup(cfg, mesh, local_steps=local_steps,
                                          algorithm=algorithm)
    return round_fn


# ---------------------------------------------------------------------------
# Prefill / decode (serve path)
# ---------------------------------------------------------------------------


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len), jnp.int32)
    ms = _memory_shape(cfg, shape.global_batch)
    memory = jax.ShapeDtypeStruct(ms, MEM_DTYPE) if ms else None
    return params, tokens, memory


def make_prefill_step(cfg: ModelConfig):
    def prefill(params, tokens, memory=None):
        logits, _ = forward(params, cfg, tokens, memory=memory)
        # return only last-position logits (next-token) to bound output size
        return logits[:, -1]
    return prefill


def serve_input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    cache = jax.eval_shape(
        lambda: make_cache(cfg, shape.global_batch, shape.seq_len))
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    ms = _memory_shape(cfg, shape.global_batch)
    memory = jax.ShapeDtypeStruct(ms, MEM_DTYPE) if ms else None
    return params, cache, token, pos, memory


def make_serve_step(cfg: ModelConfig):
    def serve(params, cache, token, pos, memory=None):
        logits, cache = decode_step(params, cfg, token, cache, pos, memory=memory)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, cache
    return serve


def _cache_leaf_spec(path, x, mesh: Mesh, batch: int):
    """Cache leaves: [n_periods, B, S, KV, hd] (attn) / rwkv / ssm states.
    Batch over dp axes; long (seq/state) dims over 'model' when divisible."""
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
    nd = x.ndim
    spec = [None] * nd
    if nd >= 2 and x.shape[1] % dp_size == 0 and x.shape[1] >= dp_size:
        spec[1] = dp
    if name in ("k", "v") and nd == 5 and x.shape[2] % mesh.shape["model"] == 0:
        spec[2] = "model"        # cache sequence dim
    elif name == "h" and nd == 4 and x.shape[2] % mesh.shape["model"] == 0:
        spec[2] = "model"        # mamba d_inner
    elif name == "conv" and nd == 4 and x.shape[3] % mesh.shape["model"] == 0:
        spec[3] = "model"
    return NamedSharding(mesh, P(*spec))


def _tp2d_spec(x, mesh: Mesh):
    """Decode-oriented 2D tensor parallelism (§Perf H4): shard each weight's
    last (output) dim over BOTH mesh axes so matmuls consume local shards
    (contracting-dim partials -> psum) and no weight all-gathers occur."""
    both = 1
    for a in ("data", "model"):
        both *= mesh.shape[a]
    shape = x.shape
    spec = [None] * len(shape)
    if len(shape) >= 2:
        if shape[-1] % both == 0 and shape[-1] >= both:
            spec[-1] = ("data", "model")
        elif shape[-1] % mesh.shape["model"] == 0:
            spec[-1] = "model"
            if shape[-2] % mesh.shape["data"] == 0 and shape[-2] >= mesh.shape["data"] * 2:
                spec[-2] = "data"
        elif shape[-2] % mesh.shape["model"] == 0:
            spec[-2] = "model"
    return NamedSharding(mesh, P(*spec))


def serve_shardings(params, cache, mesh: Mesh, batch: int, *, tp2d: bool = False):
    if tp2d:
        p_specs = jax.tree.map(lambda x: _tp2d_spec(x, mesh), params)
    else:
        p_specs = infer_pytree_specs(params, mesh)
    c_specs = jax.tree_util.tree_map_with_path(
        lambda path, x: _cache_leaf_spec(path, x, mesh, batch), cache)
    dp = dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = NamedSharding(mesh, P(dp if batch % dp_size == 0 else None, None))
    return p_specs, c_specs, tok_spec
