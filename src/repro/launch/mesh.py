"""Production meshes. Factories only — importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_batch_mesh(devices=None):
    """1-D ``("batch",)`` mesh for sharding a leading trajectory/batch axis
    (the sweep engine's flattened point x seed dimension) across devices.

    ``devices`` defaults to all visible devices. On CPU, force several host
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
    exercise the sharded path without accelerators.
    """
    import numpy as np

    from jax.sharding import Mesh

    if devices is None:
        devices = jax.devices()
    return Mesh(np.asarray(devices), ("batch",))


def make_2d_mesh(batch: int, model: int, devices=None):
    """2-D ``("batch", "model")`` mesh for the LM sweep path: the flattened
    (point x seed) trajectory axis shards over ``"batch"`` while each
    trajectory's client axis / parameter storage shards over ``"model"``
    (``repro.experiments.shard.run_sharded_2d``).

    ``batch * model`` must equal the device count. ``make_2d_mesh(n, 1)`` is
    semantically the 1-D ``("batch",)`` mesh with a degenerate model axis; on
    CPU force host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and split them
    e.g. ``make_2d_mesh(4, 2)``.
    """
    import numpy as np

    from jax.sharding import Mesh

    if devices is None:
        devices = jax.devices()
    if batch * model != len(devices):
        raise ValueError(
            f"make_2d_mesh({batch}, {model}) needs {batch * model} devices, "
            f"got {len(devices)}")
    return Mesh(np.asarray(devices).reshape(batch, model),
                ("batch", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes: ("pod","data") on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients_for(mesh) -> int:
    """pod_silo placement: one federated client per pod."""
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1
