"""Production meshes. Factories only — importing this module never touches
jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes: ("pod","data") on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def num_clients_for(mesh) -> int:
    """pod_silo placement: one federated client per pod."""
    return mesh.shape["pod"] if "pod" in mesh.axis_names else 1
