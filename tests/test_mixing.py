"""Lemma 3: ergodicity of the implicit-gossip mixing matrices."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare image: fall back to seeded-random example cases
    HAVE_HYPOTHESIS = False

from repro.core.mixing import (
    expected_w2,
    lemma3_general_bound,
    lemma3_uniform_bound,
    mixing_matrix,
    rho_of,
)


def _check_doubly_stochastic(bits):
    W = mixing_matrix(np.array(bits, bool))
    np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-12)
    assert (W >= 0).all()


if HAVE_HYPOTHESIS:

    @given(st.lists(st.booleans(), min_size=1, max_size=12))
    @settings(max_examples=200, deadline=None)
    def test_mixing_matrix_doubly_stochastic(bits):
        _check_doubly_stochastic(bits)

else:
    _rng = np.random.default_rng(0)
    _CASES = (
        [[False], [True], [True] * 12, [False] * 12]
        + [_rng.integers(0, 2, size=int(_rng.integers(1, 13))).astype(bool)
           .tolist() for _ in range(196)]
    )

    @pytest.mark.parametrize("bits", _CASES)
    def test_mixing_matrix_doubly_stochastic(bits):
        _check_doubly_stochastic(bits)


def test_w_identity_when_lone_or_empty():
    assert (mixing_matrix(np.zeros(5, bool)) == np.eye(5)).all()
    a = np.zeros(5, bool)
    a[2] = True
    assert (mixing_matrix(a) == np.eye(5)).all()


@pytest.mark.parametrize("m,c", [(4, 0.3), (6, 0.5), (8, 0.2), (5, 0.9)])
def test_lemma3_general_bound(m, c):
    """rho(E[W^2]) <= 1 - c^4 (1-(1-c)^m)^2 / 8 for p_i >= c."""
    rng = np.random.default_rng(m)
    p = rng.uniform(c, 1.0, size=m)
    M = expected_w2(p)
    rho = rho_of(M)
    assert rho < 1.0
    assert rho <= lemma3_general_bound(c, m) + 1e-9


def test_lemma3_uniform_bound():
    """k-of-m uniform selection: rho <= 1 - (k/m)^2/8."""
    import itertools
    m, k = 6, 3
    M = np.zeros((m, m))
    subsets = list(itertools.combinations(range(m), k))
    for S in subsets:
        a = np.zeros(m, bool)
        a[list(S)] = True
        W = mixing_matrix(a)
        M += W @ W
    M /= len(subsets)
    assert rho_of(M) <= lemma3_uniform_bound(k, m) + 1e-9


def test_rho_decreases_with_c():
    """Remark 2(3): larger c -> smaller rho."""
    m = 6
    rhos = []
    for c in (0.1, 0.3, 0.6, 0.9):
        rhos.append(rho_of(expected_w2(np.full(m, c))))
    assert all(a > b for a, b in zip(rhos, rhos[1:]))
