"""Roofline extraction tooling: HLO collective parser, term math, body
extrapolation — pure-function unit tests (the end-to-end path is exercised by
launch/dryrun.py against the production meshes)."""
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.launch.dryrun import _extrapolate
from repro.launch.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    Roofline,
    collective_stats,
    model_flops_for,
)

HLO = """
ENTRY %main_spmd (p0: bf16[16,4096]) -> bf16[16,4096] {
  %ag = bf16[256,4096]{1,0} all-gather(%p0), channel_id=1, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), channel_id=2, to_apply=%add
  %rs = bf16[16,256]{1,0} reduce-scatter(%y), channel_id=3
  %a2a = bf16[8,32]{1,0} all-to-all(%z), channel_id=4
  %cp = f32[4,4]{1,0} collective-permute(%w), channel_id=5
  %ag2 = (bf16[2,2]{1,0}, bf16[2,2]{1,0}) all-gather-start(%v), channel_id=6
  %dot = bf16[16,16]{1,0} dot(%a, %b)
}
"""


def test_collective_parser_kinds_and_bytes():
    st = collective_stats(HLO)
    assert st.count_by_kind["all-gather"] == 2
    assert st.bytes_by_kind["all-gather"] == 256 * 4096 * 2 + 2 * (2 * 2 * 2)
    assert st.bytes_by_kind["all-reduce"] == 1024 * 4
    assert st.bytes_by_kind["reduce-scatter"] == 16 * 256 * 2
    assert st.bytes_by_kind["all-to-all"] == 8 * 32 * 2
    assert st.bytes_by_kind["collective-permute"] == 4 * 4 * 4
    # the dot is not a collective
    assert st.total_bytes == sum(st.bytes_by_kind.values())


def test_roofline_terms_and_bottleneck():
    rf = Roofline(flops=PEAK_FLOPS, hbm_bytes=HBM_BW / 2,
                  coll_bytes=ICI_BW * 3, chips=4, model_flops=2 * PEAK_FLOPS)
    np.testing.assert_allclose(rf.t_compute, 1.0)
    np.testing.assert_allclose(rf.t_memory, 0.5)
    np.testing.assert_allclose(rf.t_collective, 3.0)
    assert rf.bottleneck == "collective"
    np.testing.assert_allclose(rf.useful_fraction, 0.5)


def test_extrapolation_linear_in_periods():
    f1 = {"flops": 10.0, "bytes": 100.0,
          "coll_bytes": {"all-gather": 4}, "coll_count": {"all-gather": 1}}
    f2 = {"flops": 16.0, "bytes": 130.0,
          "coll_bytes": {"all-gather": 6, "all-reduce": 2},
          "coll_count": {"all-gather": 2, "all-reduce": 1}}
    est = _extrapolate(f1, f2, 10)
    np.testing.assert_allclose(est["flops"], 10 + 9 * 6)     # base + 9 bodies
    np.testing.assert_allclose(est["bytes"], 100 + 9 * 30)
    assert est["coll_bytes"]["all-gather"] == 4 + 9 * 2
    assert est["coll_bytes"]["all-reduce"] == 0 + 9 * 2
    assert est["coll_count"]["all-gather"] == 10


def test_model_flops_modes():
    cfg = get_config("mixtral-8x22b")
    n = cfg.active_param_count()
    tr = model_flops_for(cfg, INPUT_SHAPES["train_4k"], mode="train")
    pf = model_flops_for(cfg, INPUT_SHAPES["prefill_32k"], mode="prefill")
    dc = model_flops_for(cfg, INPUT_SHAPES["decode_32k"], mode="decode")
    assert tr == 6.0 * n * 256 * 4096
    assert pf == 2.0 * n * 32 * 32768
    assert dc == 2.0 * n * 128          # one token per sequence
    # MoE: active << total
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
