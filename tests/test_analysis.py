"""tracelint + sanitizers: the trace-discipline gate gates itself.

Acceptance pins:

1. Every rule (R001-R006) has a fixture-proven TRUE POSITIVE and a
   neighboring negative showing the exemption that keeps the real codebase
   quiet (shape/dtype access, isinstance, `param is None`, static_argnames,
   Callable dataclass fields, zeroed replace() keys, guarded grids).
2. Suppressions: `# tracelint: disable=RXXX -- why` silences exactly that
   rule on that line; a justification-less suppression is itself a finding
   (R000).
3. The baseline ratchets: grandfathered findings pass, NEW findings fail,
   entries whose finding disappeared surface as stale, and a
   justification-less baseline entry is rejected.
4. Self-lint: `src/repro/analysis/` and this repo's committed baseline
   leave the CLI at exit 0 (the CI gate's exact invocation).
5. Runtime half: `assert_no_new_compiles` pins jit cache totals/deltas and
   degrades to a no-op without introspection; `DonationSanitizer` reports
   donation truthfully per backend.
"""
import json
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_lib
from repro.analysis.lint import lint_paths, lint_text, main
from repro.analysis.rules import RULES


def codes(findings):
    return [f.rule for f in findings]


def lint_kernel(src: str, dispatch_src=None):
    """Lint a snippet as if it lived in kernels/ (enables R006)."""
    return lint_text(src, "src/repro/kernels/fake.py",
                     dispatch_src=dispatch_src)


# ---------------------------------------------------------------------------
# R001 — python branching on traced values
# ---------------------------------------------------------------------------


def test_r001_branch_on_jit_param_positive():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if x > 0:\n"
        "        return x\n"
        "    return -x\n")
    found = lint_text(src, "m.py")
    assert codes(found) == ["R001"]
    assert found[0].line == 4


def test_r001_scan_body_and_derived_values():
    """Taint flows through assignments, and scan bodies are traced even
    without a decorator (structural detection through lax.scan)."""
    src = (
        "import jax\n"
        "def outer(xs):\n"
        "    def body(carry, x):\n"
        "        y = x * 2\n"
        "        while y > 1:\n"
        "            y = y - 1\n"
        "        return carry, y\n"
        "    return jax.lax.scan(body, 0, xs)\n")
    assert codes(lint_text(src, "m.py")) == ["R001"]


def test_r001_round_fn_convention():
    """The executor's round bodies travel by closure — caught by name."""
    src = (
        "def round_fn(state, batch):\n"
        "    assert state.round >= 0\n"
        "    return state\n")
    assert codes(lint_text(src, "m.py")) == ["R001"]


def test_r001_negatives_shape_isinstance_is_none_static():
    """The four exemptions that keep the real engine quiet: shape-derived
    values, isinstance guards, `param is None`, and static_argnames."""
    src = (
        "import jax, functools\n"
        "@functools.partial(jax.jit, static_argnames=('block',))\n"
        "def f(x, prev=None, *, block=128):\n"
        "    m, n = x.shape\n"
        "    if n > 1:\n"
        "        pass\n"
        "    if isinstance(x, tuple):\n"
        "        pass\n"
        "    if prev is None:\n"
        "        pass\n"
        "    if block > 64:\n"
        "        pass\n"
        "    return x\n")
    assert lint_text(src, "m.py") == []


def test_r001_attribute_is_none_still_flagged():
    """`param.attr is None` reaches into an argument's internals — that
    check belongs at build time (the federated.py cohort fix)."""
    src = (
        "def round_fn(state, source):\n"
        "    if source.sample_cohort is None:\n"
        "        raise ValueError('no cohort sampler')\n"
        "    return state\n")
    assert codes(lint_text(src, "m.py")) == ["R001"]


def test_r001_closure_of_untraced_factory_is_static():
    """Reads of a non-traced factory's locals are compile constants."""
    src = (
        "import jax\n"
        "def make(flag):\n"
        "    def inner(x):\n"
        "        if flag:\n"
        "            return x * 2\n"
        "        return x\n"
        "    return jax.jit(inner)\n")
    assert lint_text(src, "m.py") == []


# ---------------------------------------------------------------------------
# R002 — host syncs inside traced contexts
# ---------------------------------------------------------------------------


def test_r002_positives():
    src = (
        "import jax\n"
        "import numpy as np\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    print('round', x)\n"
        "    v = float(x)\n"
        "    w = x.item()\n"
        "    a = np.asarray(x)\n"
        "    jax.device_get(x)\n"
        "    x.block_until_ready()\n"
        "    return v + w + a\n")
    assert codes(lint_text(src, "m.py")) == ["R002"] * 6


def test_r002_negatives_host_side_and_static():
    """Host-side timing/CSV code (benchmarks/) is untraced; int(len(x))
    and np.array of a constant table are static even inside jit."""
    src = (
        "import jax\n"
        "import numpy as np\n"
        "def bench(run, batch):\n"
        "    out = run(batch)\n"
        "    print('cells/sec', float(out))\n"
        "    return np.asarray(out)\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    n = int(len(x))\n"
        "    table = np.asarray([1, 2, 3])\n"
        "    return x * n + table[0]\n")
    assert lint_text(src, "m.py") == []


# ---------------------------------------------------------------------------
# R003 — structure-only runner-cache keys
# ---------------------------------------------------------------------------


def test_r003_hparam_attr_in_key_positive():
    src = (
        "_RUNNER_CACHE = {}\n"
        "def runner_for(spec):\n"
        "    key = (spec.task, spec.lr)\n"
        "    if key not in _RUNNER_CACHE:\n"
        "        _RUNNER_CACHE[key] = object()\n"
        "    return _RUNNER_CACHE[key]\n")
    found = lint_text(src, "m.py")
    assert "R003" in codes(found)
    assert any(".lr" in f.message for f in found)


def test_r003_unzeroed_replace_positive():
    """A replace() canonicalization that forgets one hparam knob."""
    src = (
        "import dataclasses\n"
        "_RUNNER_CACHE = {}\n"
        "def runner_for(spec, fed):\n"
        "    canon = dataclasses.replace(fed, alpha=0.0, sigma0=0.0,\n"
        "                                delta=0.0)\n"
        "    key = (canon, spec.rounds)\n"
        "    return _RUNNER_CACHE.setdefault(key, object())\n")
    found = lint_text(src, "m.py")
    assert codes(found) == ["R003"]
    assert "gamma" in found[0].message


def test_r003_zeroed_replace_negative():
    """grid.py's actual contract: all knobs zeroed -> quiet."""
    src = (
        "import dataclasses\n"
        "_RUNNER_CACHE = {}\n"
        "def runner_for(spec, fed):\n"
        "    canon = dataclasses.replace(fed, alpha=0.0, sigma0=0.0,\n"
        "                                delta=0.0, gamma=0.0, period=0)\n"
        "    key = (canon, spec.rounds, spec.eval_every)\n"
        "    return _RUNNER_CACHE.setdefault(key, object())\n")
    assert lint_text(src, "m.py") == []


def test_r003_key_helper_expansion():
    """hparams hidden inside a local *_key() helper are still caught."""
    src = (
        "_RUNNER_CACHE = {}\n"
        "def _task_key(spec):\n"
        "    return (spec.task, spec.gamma)\n"
        "def runner_for(spec):\n"
        "    key = _task_key(spec)\n"
        "    return _RUNNER_CACHE.setdefault(key, object())\n")
    found = lint_text(src, "m.py")
    assert codes(found) == ["R003"]
    assert "_task_key" in found[0].message


# ---------------------------------------------------------------------------
# R004 — pytree registration
# ---------------------------------------------------------------------------

R004_POS = (
    "from dataclasses import dataclass\n"
    "import jax.numpy as jnp\n"
    "@dataclass\n"
    "class State:\n"
    "    params: jnp.ndarray\n"
    "    count: int\n")


def test_r004_unregistered_dataclass_positive():
    found = lint_text(R004_POS, "m.py")
    assert codes(found) == ["R004"]
    assert "params" in found[0].message


def test_r004_registered_dataclass_negative():
    src = R004_POS + (
        "import jax\n"
        "jax.tree_util.register_dataclass(State, data_fields=['params'],\n"
        "                                 meta_fields=['count'])\n")
    assert lint_text(src, "m.py") == []


def test_r004_callable_and_host_fields_negative():
    """Callables are behavior, not data; np.ndarray / float fields live on
    the host and never cross jit as pytrees."""
    src = (
        "from dataclasses import dataclass\n"
        "from typing import Callable\n"
        "import numpy as np\n"
        "@dataclass\n"
        "class Task:\n"
        "    loss_fn: Callable[..., 'Pytree']\n"
        "    partition: np.ndarray\n"
        "    lr: float\n")
    assert lint_text(src, "m.py") == []


# ---------------------------------------------------------------------------
# R005 — donated-buffer reuse
# ---------------------------------------------------------------------------


def test_r005_reuse_after_donation_positive():
    src = (
        "import jax\n"
        "def caller(state, batch):\n"
        "    g = jax.jit(lambda s, b: s, donate_argnums=(0,))\n"
        "    out = g(state, batch)\n"
        "    return state.round\n")
    found = lint_text(src, "m.py")
    assert codes(found) == ["R005"]
    assert "'state'" in found[0].message


def test_r005_rebind_is_fine():
    """The supported idiom: rebind the donated name from the call."""
    src = (
        "import jax\n"
        "def caller(state, batch):\n"
        "    g = jax.jit(lambda s, b: s, donate_argnums=(0,))\n"
        "    state = g(state, batch)\n"
        "    return state.round\n")
    assert lint_text(src, "m.py") == []


# ---------------------------------------------------------------------------
# R006 — pallas kernel hygiene (kernels/ scoped)
# ---------------------------------------------------------------------------


def test_r006_missing_divisibility_guard_positive():
    src = (
        "import jax\n"
        "from jax.experimental import pallas as pl\n"
        "def call(x, bn):\n"
        "    m, n = x.shape\n"
        "    return pl.pallas_call(kern, grid=(n // bn,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n")
    found = lint_kernel(src)
    assert "R006" in codes(found)
    assert "'bn'" in found[0].message


def test_r006_guarded_grid_negative():
    """Either an assert-% or a padding expression satisfies the guard."""
    asserted = (
        "from jax.experimental import pallas as pl\n"
        "def call(x, bn):\n"
        "    m, n = x.shape\n"
        "    assert n % bn == 0, (n, bn)\n"
        "    return pl.pallas_call(kern, grid=(n // bn,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n")
    padded = (
        "import jax.numpy as jnp\n"
        "from jax.experimental import pallas as pl\n"
        "def call(x, bn):\n"
        "    m, n = x.shape\n"
        "    pad = (-n) % bn\n"
        "    x = jnp.pad(x, ((0, 0), (0, pad)))\n"
        "    return pl.pallas_call(kern, grid=(x.shape[1] // bn,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n")
    assert lint_kernel(asserted) == []
    assert lint_kernel(padded) == []


def test_r006_branch_on_ref_shape_and_missing_fp32():
    src = (
        "from jax.experimental import pallas as pl\n"
        "def call(x):\n"
        "    return pl.pallas_call(kern, grid=(1,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    if x_ref.shape[0] > 1:\n"
        "        o_ref[...] = x_ref[...].sum(0)\n")
    found = lint_kernel(src)
    assert codes(found) == ["R006", "R006"]
    msgs = " | ".join(f.message for f in found)
    assert "ref shape" in msgs and "fp32" in msgs


def test_r006_fp32_accumulation_negative():
    src = (
        "import jax.numpy as jnp\n"
        "from jax.experimental import pallas as pl\n"
        "def call(x):\n"
        "    return pl.pallas_call(kern, grid=(1,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...].astype(jnp.float32).sum(0)\n")
    assert lint_kernel(src) == []


def test_r006_dispatch_routing():
    kernel_src = (
        "from jax.experimental import pallas as pl\n"
        "def call(x):\n"
        "    return pl.pallas_call(kern, grid=(1,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n")
    routed = lint_kernel(kernel_src, dispatch_src="from fake import call\n")
    unrouted = lint_kernel(kernel_src, dispatch_src="# nothing here\n")
    assert routed == []
    assert codes(unrouted) == ["R006"]
    assert "not routed" in unrouted[0].message


def test_r006_only_applies_under_kernels_dir():
    src = (
        "from jax.experimental import pallas as pl\n"
        "def call(x, bn):\n"
        "    m, n = x.shape\n"
        "    return pl.pallas_call(kern, grid=(n // bn,))(x)\n"
        "def kern(x_ref, o_ref):\n"
        "    o_ref[...] = x_ref[...]\n")
    assert lint_text(src, "src/repro/models/fake.py") == []


# ---------------------------------------------------------------------------
# Suppressions (and R000)
# ---------------------------------------------------------------------------

SUPPRESSIBLE = (
    "import jax\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    if x > 0:{comment}\n"
    "        return x\n"
    "    return -x\n")


def test_suppression_with_justification_silences():
    src = SUPPRESSIBLE.format(
        comment="  # tracelint: disable=R001 -- fixture: known-static")
    assert lint_text(src, "m.py") == []


def test_suppression_wrong_code_does_not_silence():
    src = SUPPRESSIBLE.format(
        comment="  # tracelint: disable=R002 -- wrong rule")
    assert codes(lint_text(src, "m.py")) == ["R001"]


def test_suppression_without_justification_is_r000():
    src = SUPPRESSIBLE.format(comment="  # tracelint: disable=R001")
    found = lint_text(src, "m.py")
    assert codes(found) == ["R000"]


# ---------------------------------------------------------------------------
# Baseline ratchet
# ---------------------------------------------------------------------------

DIRTY = (
    "import jax\n"
    "@jax.jit\n"
    "def f(x):\n"
    "    if x > 0:\n"
    "        return x\n"
    "    return -x\n")


def _write_tree(tmp_path, name="mod.py", src=DIRTY):
    pkg = tmp_path / "pkg"
    pkg.mkdir(exist_ok=True)
    (pkg / name).write_text(src)
    return pkg


def test_baseline_grandfathers_then_ratchets(tmp_path, capsys):
    pkg = _write_tree(tmp_path)
    base = tmp_path / "base.json"
    findings = lint_paths([str(pkg)])
    assert codes(findings) == ["R001"]

    baseline_lib.save(base, findings)
    assert main([str(pkg), "--baseline", str(base)]) == 0

    # a NEW finding (another dirty function) fails the gate
    (pkg / "mod2.py").write_text(DIRTY)
    assert main([str(pkg), "--baseline", str(base)]) == 1
    capsys.readouterr()
    assert main([str(pkg), "--baseline", str(base), "--json"]) == 1
    payload = json.loads(capsys.readouterr().out)
    assert payload["grandfathered"] == 1
    assert len(payload["findings"]) == 1
    assert payload["findings"][0]["file"].endswith("mod2.py")


def test_baseline_stale_entry_surfaces_but_passes(tmp_path, capsys):
    pkg = _write_tree(tmp_path)
    base = tmp_path / "base.json"
    baseline_lib.save(base, lint_paths([str(pkg)]))
    (pkg / "mod.py").write_text("x = 1\n")       # finding fixed
    assert main([str(pkg), "--baseline", str(base)]) == 0
    assert "stale baseline entry" in capsys.readouterr().err


def test_baseline_fingerprint_survives_line_drift(tmp_path):
    pkg = _write_tree(tmp_path)
    base = tmp_path / "base.json"
    baseline_lib.save(base, lint_paths([str(pkg)]))
    # 40 lines of prelude shift every lineno; the fingerprint holds
    (pkg / "mod.py").write_text("# pad\n" * 40 + DIRTY)
    assert main([str(pkg), "--baseline", str(base)]) == 0


def test_baseline_requires_justification(tmp_path):
    base = tmp_path / "base.json"
    base.write_text(json.dumps({"version": 1, "entries": [
        {"fingerprint": "abc", "file": "m.py", "line": 1, "rule": "R001",
         "message": "x", "justification": ""}]}))
    with pytest.raises(ValueError, match="justification"):
        baseline_lib.load(base)


def test_update_baseline_keeps_existing_justifications(tmp_path):
    pkg = _write_tree(tmp_path)
    base = tmp_path / "base.json"
    assert main([str(pkg), "--baseline", str(base),
                 "--update-baseline"]) == 0
    data = json.loads(base.read_text())
    data["entries"][0]["justification"] = "KEEP ME"
    base.write_text(json.dumps(data))
    assert main([str(pkg), "--baseline", str(base),
                 "--update-baseline"]) == 0
    data2 = json.loads(base.read_text())
    assert data2["entries"][0]["justification"] == "KEEP ME"


# ---------------------------------------------------------------------------
# Self-lint: the gate holds on this repo
# ---------------------------------------------------------------------------

REPO = Path(__file__).resolve().parent.parent


def test_self_lint_analysis_package_clean():
    findings = lint_paths([str(REPO / "src" / "repro" / "analysis")])
    assert findings == [], [f.render() for f in findings]


def test_repo_gate_exits_zero_against_committed_baseline():
    """The CI invocation, byte for byte (modulo cwd)."""
    baseline = REPO / ".tracelint-baseline.json"
    assert baseline.exists()
    entries = baseline_lib.load(baseline)
    assert all(e["justification"].strip() for e in entries.values())
    findings = lint_paths([str(REPO / "src"), str(REPO / "benchmarks")])
    # paths in the committed baseline are repo-relative; re-key on the
    # fingerprint's (file-tail, rule, text) by rebasing to repo-relative
    rel = [type(f)(file=str(Path(f.file).relative_to(REPO)), line=f.line,
                   rule=f.rule, message=f.message, line_text=f.line_text)
           for f in findings]
    new, grandfathered, _ = baseline_lib.partition(rel, entries)
    assert new == [], [f.render() for f in new]
    assert len(grandfathered) == len(entries)


def test_every_rule_documented():
    assert set(RULES) == {"R000", "R001", "R002", "R003", "R004", "R005",
                          "R006"}
    for rule in RULES.values():
        assert rule.summary and rule.name


# ---------------------------------------------------------------------------
# Runtime half: compile + donation sanitizers
# ---------------------------------------------------------------------------


def test_compile_sanitizer_pins_totals_and_deltas():
    import jax
    import jax.numpy as jnp
    from repro.analysis.sanitize import assert_no_new_compiles

    @jax.jit
    def f(x):
        return x * 2

    f(jnp.ones((2,)))
    probe = assert_no_new_compiles(f, expect_total=1)
    if not probe.has_introspection:
        pytest.skip("jit cache introspection unavailable")

    with assert_no_new_compiles(f):
        f(jnp.ones((2,)) * 3)        # same aval: no retrace

    f(jnp.ones((3,)))                # new shape: second entry
    with pytest.raises(AssertionError, match="expected exactly 1"):
        assert_no_new_compiles(f, expect_total=1)
    assert_no_new_compiles(f, expect_total=2)

    with pytest.raises(AssertionError, match="retraced"):
        with assert_no_new_compiles(f):
            f(jnp.ones((4,)))

    # allowed growth budget
    with assert_no_new_compiles(f, max_new=1):
        f(jnp.ones((5,)))


def test_compile_sanitizer_noop_without_introspection():
    from repro.analysis.sanitize import assert_no_new_compiles

    def plain(x):
        return x

    probe = assert_no_new_compiles(plain, expect_total=1)   # must not raise
    assert not probe.has_introspection
    with assert_no_new_compiles(plain):
        plain(1)


def test_donation_sanitizer_consumed_and_not_consumed():
    import jax
    import jax.numpy as jnp
    from repro.analysis.sanitize import DonationSanitizer

    # donated operand: jax invalidates the argument array (even where the
    # backend doesn't reuse the buffer, the array is marked deleted)
    run = jax.jit(lambda s: s + 1, donate_argnums=(0,))
    state = jnp.ones((8,))
    with DonationSanitizer(state, strict=True) as d:
        out = run(state)
    out.block_until_ready()
    assert not d.live_leaves()

    # un-donated operand stays live: strict mode reports it, non-strict
    # skips on backends that ignore donation (CPU)
    plain = jax.jit(lambda s: s + 1)
    state2 = jnp.ones((8,))
    d2 = DonationSanitizer(state2, strict=True)
    plain(state2).block_until_ready()
    assert d2.live_leaves()
    with pytest.raises(AssertionError, match="still live"):
        d2.assert_donated()
