"""Proposition 2: E[t - tau_i(t)] <= 1/c when p_i^t >= c — plus the
buffered-engine staleness metric and its degenerate-equality pin
(``repro.scale``): a buffered configuration that commits every round is
bit-for-bit the synchronous engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import init_fed_state, make_link_process, make_run_rounds
from repro.core.algorithms import make_algorithm_spec
from repro.data import fixed_source
from repro.optim import sgd
from repro.scale import BUFFER_METRIC_KEYS, Strategy


def test_staleness_bound_bernoulli():
    m, T = 16, 3000
    rng = np.random.default_rng(0)
    c = 0.2
    p = jnp.asarray(rng.uniform(c, 1.0, size=m))
    fed = FederationConfig(num_clients=m, scheme="bernoulli")
    link = make_link_process(p, fed)
    state = link.init(jax.random.PRNGKey(0))
    last = -np.ones(m)
    gaps = []
    key = jax.random.PRNGKey(1)
    for t in range(T):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        act = np.asarray(active)
        for i in range(m):
            if act[i]:
                if last[i] >= 0:
                    gaps.append(t - last[i])
                last[i] = t
    assert np.mean(gaps) <= 1.0 / c + 0.25  # sampling tolerance


def test_staleness_tracked_by_engine():
    from repro.core import init_fed_state, make_algorithm, make_round_fn
    from repro.optim import sgd
    m, s = 8, 2
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(jnp.full((m,), 0.5), fed)
    loss = lambda params, batch: jnp.sum(params["x"] ** 2)
    opt = sgd(0.1)
    rf = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.ones(3)}, fed, algo, link, opt)
    batches = {"u": jnp.zeros((m, s, 1))}
    staleness = []
    for t in range(200):
        st, mets = rf(st, batches)
        staleness.append(np.asarray(mets["staleness"]))
    # average staleness ~ 1/p = 2 (plus the initial -1 rounds); bounded
    assert np.mean(staleness[50:]) < 2.0 / 0.5 + 1.0


def _scale_problem(m, p):
    """A tiny quadratic problem on the real engine, fedpbc family."""
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=2)
    spec = make_algorithm_spec(("fedpbc",), fed)
    link = make_link_process(jnp.full((m,), p), fed)
    loss = lambda params, batch: jnp.sum(
        (params["x"] - batch["u"].sum()) ** 2)
    source = fixed_source({"u": jnp.zeros((m, fed.local_steps, 1))})
    return fed, spec, link, loss, sgd(0.05), source


def _run(fed, spec, link, loss, opt, source, *, rounds, strategy=None,
         metric_keys=("loss", "num_active", "staleness")):
    run = make_run_rounds(loss, opt, spec, link, fed, source,
                          metric_keys=metric_keys, donate=False,
                          strategy=strategy)
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.ones(3)}, fed,
                        spec, link, opt, buffered=strategy is not None)
    st, _, mets = run(st, source.init(jax.random.PRNGKey(2)),
                      jax.random.PRNGKey(3), rounds)
    return st, mets


def test_buffered_staleness_bounded_by_deadline():
    """Each buffered contribution waits at most deadline_rounds - 1 rounds
    before its commit, so the per-commit mean staleness is bounded by the
    deadline; with p=0.5 links the loose engine bound deadline + 1/p holds
    with plenty of margin, and commits actually happen at the deadline
    cadence (the buffer of 6 rarely fills from ~2 arrivals per round)."""
    m, p, rounds = 16, 0.5, 240
    deadline = 4
    strat = Strategy("buf", buffer_size=6, deadline_rounds=deadline)
    fed, spec, link, loss, opt, source = _scale_problem(m, p)
    st, mets = _run(fed, spec, link, loss, opt, source, rounds=rounds,
                    strategy=strat,
                    metric_keys=("staleness",) + BUFFER_METRIC_KEYS)
    commit = np.asarray(mets["commit"])
    stale = np.asarray(mets["commit_staleness"])
    n_commits = commit.sum()
    assert n_commits >= rounds / deadline            # deadline forces commits
    mean_stale = (stale * commit).sum() / n_commits
    assert 0.0 < mean_stale <= deadline + 1.0 / p
    # and per-commit staleness never exceeds the deadline itself
    assert stale.max() <= deadline
    assert float(np.asarray(st.buffer.commits)) == n_commits


def test_degenerate_buffered_equals_sync_bit_for_bit():
    """The pin: a buffered configuration that commits every round IS the
    synchronous engine — same server, same clients, same metrics, bitwise.
    Two degenerate routes: wait_for_full with a buffer the (all-active)
    round always fills, and deadline_rounds=1 under partial activity."""
    m, rounds = 8, 12
    cases = [
        (1.0, Strategy("deg_full", wait_for_full=True, buffer_size=m)),
        (0.5, Strategy("deg_deadline", deadline_rounds=1)),
    ]
    for p, strat in cases:
        fed, spec, link, loss, opt, source = _scale_problem(m, p)
        st_ref, mets_ref = _run(fed, spec, link, loss, opt, source,
                                rounds=rounds)
        st_buf, mets_buf = _run(fed, spec, link, loss, opt, source,
                                rounds=rounds, strategy=strat)
        for a, b in zip(jax.tree.leaves((st_ref.server, st_ref.clients,
                                         st_ref.last_active)),
                        jax.tree.leaves((st_buf.server, st_buf.clients,
                                         st_buf.last_active))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for k in ("loss", "num_active", "staleness"):
            np.testing.assert_array_equal(np.asarray(mets_ref[k]),
                                          np.asarray(mets_buf[k]))
        # the degenerate policy committed every round with an empty buffer
        assert int(np.asarray(st_buf.buffer.commits)) == rounds
        assert float(np.asarray(st_buf.buffer.weight)) == 0.0
