"""Proposition 2: E[t - tau_i(t)] <= 1/c when p_i^t >= c."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import make_link_process


def test_staleness_bound_bernoulli():
    m, T = 16, 3000
    rng = np.random.default_rng(0)
    c = 0.2
    p = jnp.asarray(rng.uniform(c, 1.0, size=m))
    fed = FederationConfig(num_clients=m, scheme="bernoulli")
    link = make_link_process(p, fed)
    state = link.init(jax.random.PRNGKey(0))
    last = -np.ones(m)
    gaps = []
    key = jax.random.PRNGKey(1)
    for t in range(T):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        act = np.asarray(active)
        for i in range(m):
            if act[i]:
                if last[i] >= 0:
                    gaps.append(t - last[i])
                last[i] = t
    assert np.mean(gaps) <= 1.0 / c + 0.25  # sampling tolerance


def test_staleness_tracked_by_engine():
    from repro.core import init_fed_state, make_algorithm, make_round_fn
    from repro.optim import sgd
    m, s = 8, 2
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(jnp.full((m,), 0.5), fed)
    loss = lambda params, batch: jnp.sum(params["x"] ** 2)
    opt = sgd(0.1)
    rf = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.ones(3)}, fed, algo, link, opt)
    batches = {"u": jnp.zeros((m, s, 1))}
    staleness = []
    for t in range(200):
        st, mets = rf(st, batches)
        staleness.append(np.asarray(mets["staleness"]))
    # average staleness ~ 1/p = 2 (plus the initial -1 rounds); bounded
    assert np.mean(staleness[50:]) < 2.0 / 0.5 + 1.0
