"""Traced hyperparameter axes of the batched sweep core.

Two guarantees back the "traced-everything" design:

1. Substituting traced inputs for compile-time constants changes NOTHING
   numerically: every (hyperparameter point, seed) trajectory of
   ``make_batched_run_rounds`` — traced lr, traced gamma, traced Eq.-9
   ``p_base``, traced dataset arrays and partition — is bit-for-bit equal to
   a sequential ``make_run_rounds`` run with that point's knobs baked as
   constants (the pre-refactor execution model).
2. Because swept values are traced, a value-only ablation compiles ONCE per
   (algorithm, scheme): the runner's two jitted stages report a single cache
   entry across an alpha/sigma0/delta/lr/gamma sweep, and the executor's
   runner cache hands back the same object for specs differing only in
   swept values.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_run_rounds,
)
from repro.experiments import SweepSpec, make_classification_task, seed_keys
from repro.experiments.grid import (
    _RUNNER_CACHE,
    _runner_for,
    get_traced_task,
    make_cell_batch,
    point_base_probs,
    run_cell_batch,
)
from repro.optim import paper_decay, sgd

M, S_LOCAL, B = 8, 3, 4
SEEDS = (0, 1)
BASE = SweepSpec(seeds=SEEDS, num_clients=M, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=B, local_steps=S_LOCAL, rounds=5, eval_every=2)
METRIC_KEYS = ("loss", "num_active")


def _constant_task(spec, alpha):
    """The constant-capturing task at one point's alpha (dataset + partition
    baked as jit constants, the pre-refactor data path)."""
    return make_classification_task(
        data_seed=spec.data_seed, num_clients=spec.num_clients, dim=spec.dim,
        classes=spec.classes, hidden=spec.hidden, n_per_class=spec.n_per_class,
        n_train=spec.n_train, alpha=alpha, per_client=spec.per_client,
        local_steps=spec.local_steps, batch_size=spec.batch_size)


def _sequential_point(spec, algo_name, scheme, point, seed, p_base_row,
                      chunks):
    """One trajectory on the sequential ``make_run_rounds`` path with the
    point's lr/gamma/alpha baked as constants; evals at chunk boundaries."""
    task = _constant_task(spec, point["alpha"])
    fed = dataclasses.replace(spec.cell_config(algo_name, scheme),
                              gamma=point["gamma"], alpha=point["alpha"],
                              sigma0=point["sigma0"], delta=point["delta"])
    algo = make_algorithm(fed)
    opt = sgd(paper_decay(point["lr"]))
    link = make_link_process(p_base_row, fed)
    run_rounds = make_run_rounds(task.loss_fn, opt, algo, link, fed,
                                 task.source, metric_keys=METRIC_KEYS,
                                 donate=False)
    ks = seed_keys(seed)
    st = init_fed_state(ks["state"], task.init_params(ks["params"]), fed,
                        algo, link, opt)
    ds = task.source.init(ks["ds"])
    collected, evals = [], []
    for c in chunks:
        st, ds, mets = run_rounds(st, ds, ks["data"], c)
        collected.append(mets)
        evals.append(task.eval_test(st.server))
    mets = jax.tree.map(lambda *xs: jnp.concatenate(xs), *collected)
    return st, mets, jnp.stack(evals)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("algo_name,scheme", [
    ("fedpbc", "bernoulli_tv"),
    ("fedavg", "markov_nonhom"),
])
def test_traced_points_match_static_sequential_bit_for_bit(algo_name, scheme):
    """lr x alpha axes (4 points x 2 seeds in ONE program) vs 8 independent
    constant-baked sequential runs: states, metrics, and in-scan evals must
    be bitwise identical per trajectory."""
    spec = dataclasses.replace(BASE, lrs=(0.05, 0.1), alphas=(0.1, 1.0))
    task = get_traced_task(spec)
    fed = spec.cell_config(algo_name, scheme)
    runner = _runner_for(spec, fed, task, METRIC_KEYS)
    batch = make_cell_batch(spec, fed, task)
    states, out = runner(batch)

    points = spec.hparam_points()
    S = len(SEEDS)
    assert out["evals"].shape == (len(points) * S, 3)  # rounds 2, 4, 5
    for pi, pt in enumerate(points):
        p_base = point_base_probs(spec, pt)
        for si, seed in enumerate(SEEDS):
            b = pi * S + si
            st_seq, mets_seq, evals_seq = _sequential_point(
                spec, algo_name, scheme, pt, seed, p_base[si],
                chunks=(2, 2, 1))
            _assert_trees_equal(jax.tree.map(lambda x: x[b], states), st_seq)
            for k in METRIC_KEYS:
                np.testing.assert_array_equal(
                    np.asarray(out["metrics"][k][b]), np.asarray(mets_seq[k]))
            np.testing.assert_array_equal(np.asarray(out["evals"][b]),
                                          np.asarray(evals_seq))


def test_traced_gamma_matches_static_sequential_bit_for_bit():
    """A gamma axis (Eq.-9 dynamics as traced scalars) must reproduce the
    gamma-baked link process exactly, including the time-varying p_t the
    known-p algorithms consume."""
    spec = dataclasses.replace(BASE, gammas=(0.1, 0.9), seeds=(0,))
    task = get_traced_task(spec)
    fed = spec.cell_config("fedavg_known_p", "bernoulli_tv")
    runner = _runner_for(spec, fed, task, METRIC_KEYS)
    batch = make_cell_batch(spec, fed, task)
    states, out = runner(batch)

    for pi, pt in enumerate(spec.hparam_points()):
        p_base = point_base_probs(spec, pt)
        st_seq, mets_seq, _ = _sequential_point(
            spec, "fedavg_known_p", "bernoulli_tv", pt, 0, p_base[0],
            chunks=(2, 2, 1))
        _assert_trees_equal(jax.tree.map(lambda x: x[pi], states), st_seq)
        for k in METRIC_KEYS:
            np.testing.assert_array_equal(
                np.asarray(out["metrics"][k][pi]), np.asarray(mets_seq[k]))


def test_value_ablation_reuses_one_compile():
    """An alpha/sigma0/delta/lr/gamma ablation is served by ONE compiled
    (init, scan) pair per (algorithm, scheme): re-running with entirely
    different swept values (same grid shape) adds zero compile-cache entries
    and zero executor runner-cache entries."""
    # distinct rounds/eval_every -> a runner of this test's own (a runner is
    # shared per structural key, so other tests' batch shapes would otherwise
    # legitimately add shape-keyed cache entries)
    spec = dataclasses.replace(BASE, rounds=4, eval_every=0,
                               lrs=(0.05, 0.1), alphas=(0.1, 1.0),
                               gammas=(0.1, 0.9), sigma0s=(1.0, 10.0),
                               deltas=(0.02, 0.1))
    run_cell_batch(spec, "fedpbc", "bernoulli_tv", metric_keys=METRIC_KEYS)
    fed = spec.cell_config("fedpbc", "bernoulli_tv")
    runner = _runner_for(spec, fed, get_traced_task(spec), METRIC_KEYS)
    if not hasattr(runner.scan_batch, "_cache_size"):
        pytest.skip("jax.jit cache introspection unavailable")
    assert runner.init_batch._cache_size() == 1
    assert runner.scan_batch._cache_size() == 1

    n_runners = len(_RUNNER_CACHE)
    spec2 = dataclasses.replace(spec, lrs=(0.2, 0.01), alphas=(0.5, 5.0),
                                gammas=(0.3, 0.7), sigma0s=(2.0, 5.0),
                                deltas=(0.001, 0.05))
    cells = run_cell_batch(spec2, "fedpbc", "bernoulli_tv",
                           metric_keys=METRIC_KEYS)
    assert len(cells) == 32 and len(_RUNNER_CACHE) == n_runners
    runner2 = _runner_for(spec2, spec2.cell_config("fedpbc", "bernoulli_tv"),
                          get_traced_task(spec2), METRIC_KEYS)
    assert runner2 is runner
    assert runner.init_batch._cache_size() == 1
    assert runner.scan_batch._cache_size() == 1


def test_period_override_shares_compile_and_changes_trajectory():
    """``fed_overrides=(("period", P),)`` is a traced hparam, not a compile
    knob: the runner cache zeroes ``period`` in its key, so two specs
    differing only in the override must hand back the SAME runner with no
    new jit entries — yet the traced ``hp["period"]`` input must actually be
    wired from the override, i.e. the trajectories must differ AND match a
    sequential run with that period baked into the link process."""
    spec20 = dataclasses.replace(BASE, rounds=5, eval_every=3, seeds=(0,),
                                 fed_overrides=(("period", 20),))
    spec40 = dataclasses.replace(spec20, fed_overrides=(("period", 40),))

    # the override reaches the traced input
    fed20 = spec20.cell_config("fedpbc", "bernoulli_tv")
    batch20 = make_cell_batch(spec20, fed20, get_traced_task(spec20))
    np.testing.assert_array_equal(np.asarray(batch20.hparams["period"]),
                                  np.full((1,), 20.0, np.float32))

    cells20 = run_cell_batch(spec20, "fedpbc", "bernoulli_tv",
                             metric_keys=METRIC_KEYS, mesh=None)
    runner = _runner_for(spec20, fed20, get_traced_task(spec20), METRIC_KEYS)
    n_runners = len(_RUNNER_CACHE)
    has_introspection = hasattr(runner.scan_batch, "_cache_size")
    if has_introspection:
        n_entries = (runner.init_batch._cache_size()
                     + runner.scan_batch._cache_size())

    cells40 = run_cell_batch(spec40, "fedpbc", "bernoulli_tv",
                             metric_keys=METRIC_KEYS, mesh=None)
    # one compile serves both periods...
    assert len(_RUNNER_CACHE) == n_runners
    assert _runner_for(spec40, spec40.cell_config("fedpbc", "bernoulli_tv"),
                       get_traced_task(spec40), METRIC_KEYS) is runner
    if has_introspection:
        assert (runner.init_batch._cache_size()
                + runner.scan_batch._cache_size()) == n_entries
    # ...but the trajectories differ: period shapes p_of_t, which drives the
    # Bernoulli activations (num_active is the link process's fingerprint;
    # a loss difference would only surface once an aggregation diverges)
    assert not np.array_equal(cells20[0].num_active, cells40[0].num_active)

    # and each matches the sequential path with its period BAKED into the
    # link process (cell_config carries the override into fed.period)
    for spec, cells in ((spec20, cells20), (spec40, cells40)):
        pt = spec.hparam_points()[0]
        p_base = point_base_probs(spec, pt)
        _, mets_seq, evals_seq = _sequential_point(
            spec, "fedpbc", "bernoulli_tv", pt, 0, p_base[0], chunks=(3, 2))
        np.testing.assert_array_equal(np.asarray(cells[0].loss[0]),
                                      np.asarray(mets_seq["loss"]))
        np.testing.assert_array_equal(np.asarray(cells[0].num_active[0]),
                                      np.asarray(mets_seq["num_active"]))
        np.testing.assert_array_equal(np.asarray(cells[0].test_acc[0]),
                                      np.asarray(evals_seq))


def test_label_noise_shared_swap_reuses_compile_without_new_task():
    """The ROADMAP "traced dataset swaps" path: a same-shape label-noise
    variant of the dataset rides the traced ``shared`` input of an already
    compiled runner — no new task object, no new partition, zero new jit
    entries — and the swap is actually wired (trajectories change)."""
    import repro.experiments.grid as grid_mod
    from repro.experiments.tasks import with_label_noise

    spec = dataclasses.replace(BASE, rounds=4, eval_every=2)
    task = get_traced_task(spec)
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    runner = _runner_for(spec, fed, task, METRIC_KEYS)
    batch = make_cell_batch(spec, fed, task)
    states, out = runner(batch)
    has_introspection = hasattr(runner.scan_batch, "_cache_size")
    if has_introspection:
        n_entries = (runner.init_batch._cache_size()
                     + runner.scan_batch._cache_size())
    n_tasks = len(grid_mod._TRACED_TASK_CACHE)

    noisy = with_label_noise(task.shared, jax.random.PRNGKey(7), frac=0.5,
                             classes=spec.classes)
    # same shapes/dtypes, different labels, untouched features
    assert noisy["y"].shape == task.shared["y"].shape
    assert noisy["y"].dtype == task.shared["y"].dtype
    assert not np.array_equal(np.asarray(noisy["y"]),
                              np.asarray(task.shared["y"]))
    np.testing.assert_array_equal(np.asarray(noisy["x"]),
                                  np.asarray(task.shared["x"]))

    states2, out2 = runner(dataclasses.replace(batch, shared=noisy))
    if has_introspection:
        assert (runner.init_batch._cache_size()
                + runner.scan_batch._cache_size()) == n_entries
    assert len(grid_mod._TRACED_TASK_CACHE) == n_tasks
    # the variant reached the training loop and the in-scan eval
    assert not np.array_equal(np.asarray(out2["metrics"]["loss"]),
                              np.asarray(out["metrics"]["loss"]))
    assert not np.array_equal(np.asarray(out2["evals"]),
                              np.asarray(out["evals"]))


def test_hparam_points_flattening_and_result_coords():
    """Point-major flattening: every CellResult carries its coordinates, in
    ``itertools.product`` order over (lr, gamma, alpha, sigma0, delta)."""
    spec = dataclasses.replace(BASE, lrs=(0.05, 0.1), deltas=(0.02, 0.1))
    points = spec.hparam_points()
    assert [(p["lr"], p["delta"]) for p in points] == [
        (0.05, 0.02), (0.05, 0.1), (0.1, 0.02), (0.1, 0.1)]
    # run_cell is single-point only and must refuse BEFORE running anything
    from repro.experiments import run_cell
    with pytest.raises(ValueError, match="4 hyperparameter points"):
        run_cell(spec, "fedpbc", "bernoulli_ti")
    cells = run_cell_batch(spec, "fedpbc", "bernoulli_ti",
                           metric_keys=METRIC_KEYS)
    assert [c.hparams for c in cells] == points
    for c in cells:
        assert c.test_acc.shape == (len(SEEDS), 3)
        assert c.loss.shape == (len(SEEDS), spec.rounds)
        # un-swept knobs are recorded at their scalar defaults
        assert c.hparams["alpha"] == spec.alpha
        assert c.hparams["gamma"] == spec.gamma
