"""Teacher-forcing equivalence: step-by-step decode against the cache must
reproduce full-sequence forward logits for every cache type (KV, SWA ring
buffer, RWKV state, Mamba state, cross-attention memory)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import decode_step, forward, init_params, make_cache

CASES = [
    ("smollm-135m", 40),          # dense GQA, full attention
    ("mixtral-8x22b", 96),        # MoE + SWA ring buffer (window 64 < T)
    ("gemma2-9b", 96),            # local/global alternation + softcaps
    ("rwkv6-3b", 80),             # RWKV6 state carry
    ("jamba-1.5-large-398b", 40), # Mamba conv+ssm state + MoE + attn
    ("seamless-m4t-medium", 24),  # enc-dec cross-attention
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,T", CASES)
def test_decode_matches_forward(arch, T):
    cfg = dataclasses.replace(reduced(get_config(arch)), dtype="float32")
    if cfg.moe:  # avoid capacity-drop mismatch between batched/1-token paths
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    tokens = jax.random.randint(key, (1, T), 0, cfg.vocab_size)
    mem = None
    if cfg.family == "vlm":
        mem = 0.1 * jax.random.normal(key, (1, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        mem = 0.1 * jax.random.normal(key, (1, cfg.num_audio_frames, cfg.d_model))
    ref, _ = forward(params, cfg, tokens, memory=mem)
    cache = make_cache(cfg, 1, T)
    step = jax.jit(lambda tok, c, p: decode_step(params, cfg, tok, c, p, memory=mem))
    outs = []
    for t in range(T):
        lg, cache = step(tokens[:, t:t + 1], cache, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - ref))) / float(jnp.max(jnp.abs(ref)))
    assert rel < 2e-3, (arch, rel)
