"""Pallas kernels vs. pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps and hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare image: fall back to seeded-random example cases
    HAVE_HYPOTHESIS = False

from repro.kernels.ops import (
    FUSED_OPS,
    OP_ALL,
    OP_KNOWN_P,
    OP_MEAN,
    flash_attention,
    flash_attention_ref,
    fused_agg,
    fused_agg_pytree,
    fused_masked_agg,
    fused_masked_agg_ref,
    gqa_flash_attention,
    masked_agg,
    masked_agg_pytree,
    masked_agg_ref,
    resolve_backend,
    resolve_use_kernel,
    rwkv6_chunk,
    rwkv6_chunk_ref,
    use_kernel_default,
)


# ---------------------------------------------------------------------------
# masked_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,dtype", [
    (4, 128, jnp.float32), (8, 1000, jnp.float32), (16, 4097, jnp.bfloat16),
    (3, 64, jnp.float32), (100, 257, jnp.bfloat16),
])
def test_masked_agg_sweep(m, n, dtype):
    key = jax.random.PRNGKey(m * n)
    x = jax.random.normal(key, (m, n), jnp.float32).astype(dtype)
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (m,)) < 0.5)
    out = masked_agg(x, mask, block_n=256)
    ref = masked_agg_ref(x, mask)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


def _check_masked_agg(m, n, bits):
    mask = jnp.asarray([(bits >> i) & 1 for i in range(m)], jnp.float32)
    x = jnp.arange(m * n, dtype=jnp.float32).reshape(m, n)
    out = masked_agg(x, mask, block_n=128)
    ref = masked_agg_ref(x, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 12), st.integers(1, 300), st.integers(0, 2 ** 12 - 1))
    @settings(max_examples=25, deadline=None)
    def test_masked_agg_property(m, n, bits):
        _check_masked_agg(m, n, bits)

else:
    _rng = np.random.default_rng(0)
    _CASES = (
        # edge cases hypothesis would shrink to: single row, empty/full masks
        [(1, 1, 0), (1, 1, 1), (12, 300, 0), (12, 300, 2 ** 12 - 1)]
        + [(int(_rng.integers(1, 13)), int(_rng.integers(1, 301)),
            int(_rng.integers(0, 2 ** 12))) for _ in range(21)]
    )

    @pytest.mark.parametrize("m,n,bits", _CASES)
    def test_masked_agg_property(m, n, bits):
        _check_masked_agg(m, n, bits)


def test_masked_agg_pytree_matches_engine():
    from repro.core import masked_mean
    key = jax.random.PRNGKey(7)
    clients = {"a": jax.random.normal(key, (6, 10, 3)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 5))}
    mask = jnp.asarray([1, 1, 0, 1, 0, 0], jnp.float32)
    got = masked_agg_pytree(clients, mask)
    want = masked_mean(clients, mask)
    for k in clients:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)


def test_masked_agg_zero_active_semantics():
    """The zero-active-round contract: without ``prev`` an empty active set
    yields the zero vector — exactly ``algorithms.masked_mean``'s fallback —
    and with ``prev`` the kernel preserves the previous server params (the
    engine's ``any_active`` guard, folded in) instead of zeroing the model."""
    from repro.core import masked_mean
    key = jax.random.PRNGKey(5)
    m, n = 6, 300
    x = jax.random.normal(key, (m, n))
    prev = jax.random.normal(jax.random.fold_in(key, 1), (n,))
    empty = jnp.zeros((m,), bool)
    # legacy / masked_mean semantics: empty -> zeros
    np.testing.assert_array_equal(np.asarray(masked_agg(x, empty)),
                                  np.zeros(n, np.float32))
    np.testing.assert_array_equal(np.asarray(masked_mean(x, empty)),
                                  np.zeros((n,), np.float32))
    # guarded semantics: empty -> prev, bit for bit
    np.testing.assert_array_equal(np.asarray(masked_agg(x, empty, prev)),
                                  np.asarray(prev, np.float32))
    np.testing.assert_array_equal(
        np.asarray(masked_agg_ref(x, empty, prev)),
        np.asarray(prev, np.float32))
    # with any client active, prev is inert: both forms agree exactly
    some = jnp.arange(m) < 2
    np.testing.assert_array_equal(np.asarray(masked_agg(x, some, prev)),
                                  np.asarray(masked_agg(x, some)))
    # pytree form
    tree_x = {"w": x.reshape(m, 30, 10), "b": x[:, :4]}
    tree_prev = {"w": prev.reshape(30, 10), "b": prev[:4]}
    got = masked_agg_pytree(tree_x, empty, tree_prev)
    for k in tree_x:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(tree_prev[k]))


# ---------------------------------------------------------------------------
# fused batched family aggregation
# ---------------------------------------------------------------------------


# The exactness contract is between JITTED programs — that's how the hot
# path runs both sides (the whole sweep is one jit). Op-by-op eager dispatch
# of the pure-jnp reference can fuse multiply+reduce differently at ulp
# level, so every bitwise assertion below compares jitted callables.
_fused_jit = jax.jit(
    lambda x, mask, op, prev, p, block_n: fused_masked_agg(
        x, mask, op, prev, p, block_n=block_n),
    static_argnames="block_n")
_fused_ref_jit = jax.jit(fused_masked_agg_ref)


def _fused_case(key, B, m, n, dtype=jnp.float32, mask_kind="random"):
    x = jax.random.normal(key, (B, m, n), jnp.float32).astype(dtype)
    if mask_kind == "zeros":
        mask = jnp.zeros((B, m), bool)
    elif mask_kind == "ones":
        mask = jnp.ones((B, m), bool)
    else:
        mask = jax.random.uniform(jax.random.fold_in(key, 1), (B, m)) < 0.5
    prev = jax.random.normal(jax.random.fold_in(key, 2), (B, n),
                             jnp.float32).astype(dtype)
    p = jax.random.uniform(jax.random.fold_in(key, 3), (B, m),
                           minval=0.05, maxval=1.0)
    ops = jnp.asarray([(OP_MEAN, OP_ALL, OP_KNOWN_P)[b % 3]
                       for b in range(B)], jnp.int32)
    return x, mask, ops, prev, p


@pytest.mark.parametrize("B,m,n,mask_kind", [
    (4, 8, 512, "random"),
    (3, 13, 257, "random"),      # m not a multiple of 8, n not of block
    (2, 3, 100, "zeros"),        # no client active on any trajectory
    (2, 5, 130, "ones"),         # every client active
    (5, 100, 1000, "random"),
])
def test_fused_masked_agg_vs_ref(B, m, n, mask_kind):
    key = jax.random.PRNGKey(B * m + n)
    x, mask, ops, prev, p = _fused_case(key, B, m, n, mask_kind=mask_kind)
    got = _fused_jit(x, mask, ops, prev, p, block_n=128)
    ref = _fused_ref_jit(x, mask, ops, prev, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # the native [B, m, n] grid and vmap over the 2-D kernel agree exactly
    via_vmap = jax.jit(jax.vmap(lambda *a: fused_masked_agg(*a, block_n=128)))(
        x, mask, ops, prev, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(via_vmap))


def test_fused_masked_agg_zero_active_preserves_prev():
    """An all-inactive trajectory returns the previous server params under
    EVERY opcode (mean is guarded; the delta branches weight by the mask)."""
    key = jax.random.PRNGKey(9)
    B, m, n = 3, 7, 200
    x, _, _, prev, p = _fused_case(key, B, m, n)
    mask = jnp.zeros((B, m), bool)
    ops = jnp.asarray([OP_MEAN, OP_ALL, OP_KNOWN_P], jnp.int32)
    out = fused_masked_agg(x, mask, ops, prev, p, block_n=128)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(prev))


def test_fused_masked_agg_bf16_fp32_accumulation():
    """bf16 inputs accumulate in fp32: the kernel output matches the fp32
    oracle run on the SAME bf16-quantized inputs exactly (no bf16-precision
    reduction error on top of the input quantization)."""
    key = jax.random.PRNGKey(21)
    B, m, n = 4, 16, 513
    x, mask, ops, prev, p = _fused_case(key, B, m, n, dtype=jnp.bfloat16)
    got = _fused_jit(x, mask, ops, prev, p, block_n=256)
    assert got.dtype == jnp.float32
    ref = _fused_ref_jit(x, mask, ops, prev, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # and stays close to the full-fp32 computation (quantization error only)
    full = _fused_ref_jit(x.astype(jnp.float32), mask, ops,
                          prev.astype(jnp.float32), p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def _check_fused(m, n, bits, op):
    mask = jnp.asarray([(bits >> i) & 1 for i in range(m)], jnp.float32)
    x = jnp.arange(m * n, dtype=jnp.float32).reshape(m, n) / (m * n)
    prev = jnp.linspace(-1.0, 1.0, n)
    p = jnp.linspace(0.1, 0.9, m)
    got = _fused_jit(x, mask, jnp.int32(op), prev, p, block_n=128)
    ref = _fused_ref_jit(x, mask, jnp.int32(op), prev, p)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 12), st.integers(1, 300), st.integers(0, 2 ** 12 - 1),
           st.integers(0, 2))
    @settings(max_examples=25, deadline=None)
    def test_fused_masked_agg_property(m, n, bits, op):
        _check_fused(m, n, bits, op)

else:
    _rng_f = np.random.default_rng(1)
    _FCASES = (
        [(1, 1, 0, 0), (1, 1, 1, 2), (12, 300, 0, 1),
         (12, 300, 2 ** 12 - 1, 2)]
        + [(int(_rng_f.integers(1, 13)), int(_rng_f.integers(1, 301)),
            int(_rng_f.integers(0, 2 ** 12)), int(_rng_f.integers(0, 3)))
           for _ in range(21)]
    )

    @pytest.mark.parametrize("m,n,bits,op", _FCASES)
    def test_fused_masked_agg_property(m, n, bits, op):
        _check_fused(m, n, bits, op)


def test_fused_agg_pytree_matches_engine_branches():
    """Per-leaf fused aggregation == the engine's branch math over a ragged
    params pytree, for every opcode.

    Tolerance note: kernel and engine are separate jitted programs here, and
    XLA may schedule the kernel's fused three-branch body's reduces
    differently from the engine's standalone reduce — up to one ulp apart on
    CPU. The sweep-level tests (test_kernel_sweep.py) pin exact program-to-
    program equality at the engine's real shapes; this cross-program check
    asserts the documented <=1-ulp contract."""
    from repro.core.algorithms import masked_mean, weighted_sum
    key = jax.random.PRNGKey(13)
    m = 6
    x_star = {"w1": jax.random.normal(key, (m, 10, 3)),
              "b1": jax.random.normal(jax.random.fold_in(key, 1), (m, 3)),
              "s": jax.random.normal(jax.random.fold_in(key, 2), (m,))}
    server = {"w1": jax.random.normal(jax.random.fold_in(key, 3), (10, 3)),
              "b1": jax.random.normal(jax.random.fold_in(key, 4), (3,)),
              "s": jax.random.normal(jax.random.fold_in(key, 5), ())}
    active = jnp.asarray([1, 0, 1, 1, 0, 0], bool)
    p = jax.random.uniform(jax.random.fold_in(key, 6), (m,),
                           minval=0.1, maxval=1.0)

    kern = jax.jit(fused_agg_pytree, static_argnames="op")

    def engine(op):
        if op == OP_MEAN:
            return masked_mean(x_star, active)  # any_active is True here
        w = active.astype(jnp.float32) / m
        if op == OP_KNOWN_P:
            w = active.astype(jnp.float32) / jnp.maximum(p, 1e-3) / m
        delta = jax.tree.map(lambda xs, s: xs - s[None], x_star, server)
        return jax.tree.map(lambda s, u: s + u, server,
                            weighted_sum(delta, w))

    for op in (OP_MEAN, OP_ALL, OP_KNOWN_P):
        got = kern(x_star, active, op, server, p)
        want = jax.jit(lambda op=op: engine(op))()
        for k in x_star:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       rtol=3e-7, atol=3e-7)


# ---------------------------------------------------------------------------
# dispatch layer
# ---------------------------------------------------------------------------


def test_resolve_backend_defaults_and_env(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    # this suite runs on CPU (conftest pins JAX_PLATFORMS=cpu)
    assert resolve_backend() == "interpret"
    assert resolve_backend("xla") == "xla"
    assert resolve_backend("compiled") == "compiled"
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "xla")
    assert resolve_backend() == "xla"
    assert resolve_backend("interpret") == "interpret"   # arg wins over env
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_backend("triton")
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "cuda")
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_backend()


def test_resolve_use_kernel_env(monkeypatch):
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    assert use_kernel_default() is False
    assert resolve_use_kernel(None) is False
    assert resolve_use_kernel(True) is True
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert use_kernel_default() is True
    assert resolve_use_kernel(None) is True
    assert resolve_use_kernel(False) is False            # arg wins over env
    monkeypatch.setenv("REPRO_USE_KERNEL", "off")
    assert use_kernel_default() is False


def test_fused_agg_xla_backend_bitwise_vs_interpret():
    """The always-available XLA fallback path and the interpret-mode kernel
    implement the same fp32 math: bitwise-equal outputs."""
    key = jax.random.PRNGKey(17)
    x, mask, ops, prev, p = _fused_case(key, 4, 9, 300)
    call = jax.jit(fused_agg, static_argnames=("backend", "block_n"))
    a = call(x, mask, ops, prev, p, backend="interpret", block_n=128)
    b = call(x, mask, ops, prev, p, backend="xla")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_ops_table_covers_exactly_the_empty_state_family():
    from repro.core.algorithms import AlgorithmSpec, algo_family
    assert set(FUSED_OPS) == set(algo_family("fedavg"))
    assert AlgorithmSpec(algo_family("fedavg")).fusable
    assert AlgorithmSpec(("fedpbc",)).fusable
    assert not AlgorithmSpec(("fedau",)).fusable
    assert not AlgorithmSpec(("mifa",)).fusable


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,d,win,cap,dtype", [
    (2, 2, 256, 64, 0, 0.0, jnp.float32),
    (1, 3, 256, 128, 0, 0.0, jnp.float32),
    (1, 2, 256, 64, 128, 0.0, jnp.float32),     # sliding window
    (1, 2, 128, 64, 0, 50.0, jnp.float32),      # gemma softcap
    (1, 2, 256, 64, 0, 0.0, jnp.bfloat16),
])
def test_flash_attention_sweep(b, h, t, d, win, cap, dtype):
    key = jax.random.PRNGKey(t + d)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, h, t, d),
                                 jnp.float32).astype(dtype) for i in range(3))
    out = flash_attention(q, k, v, window=win, logit_softcap=cap)
    ref = flash_attention_ref(q, k, v, window=win, logit_softcap=cap)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_gqa_wrapper():
    key = jax.random.PRNGKey(3)
    b, t, h, kv, d = 1, 128, 4, 2, 64
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d))
    out = gqa_flash_attention(q, k, v)
    from repro.models.attention import attention
    ref = attention(q, k, v, kind="full", chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# attention dispatch (the masked_agg-style backend audit for the LM path)
# ---------------------------------------------------------------------------


def _qkv_gqa(key, b=2, t=64, h=4, kv=2, d=16):
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d))
    return q, k, v


def test_resolve_attention_backend_defaults_and_env(monkeypatch):
    """CPU default is "xla" (the chunked reference IS the fast CPU path);
    REPRO_KERNEL_BACKEND and the explicit arg override it, unknown names
    raise."""
    from repro.kernels.dispatch import resolve_attention_backend
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    expect = "compiled" if jax.default_backend() in ("tpu", "gpu") else "xla"
    assert resolve_attention_backend() == expect
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "interpret")
    assert resolve_attention_backend() == "interpret"
    assert resolve_attention_backend("xla") == "xla"
    with pytest.raises(ValueError, match="unknown kernel backend"):
        resolve_attention_backend("metal")


def test_attention_cpu_routing_is_bitwise_reference(monkeypatch):
    """On CPU the dispatched model entry resolves to the pure-XLA reference
    — routing through the dispatch layer must not change a single bit of
    the model forward."""
    from repro.models.attention import attention, attention_ref
    monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
    if jax.default_backend() != "cpu":
        pytest.skip("CPU routing contract")
    q, k, v = _qkv_gqa(jax.random.PRNGKey(0))
    for kw in (dict(kind="full"), dict(kind="swa", window=32),
               dict(kind="full", logit_softcap=30.0),
               dict(kind="chunked", window=16)):
        out = attention(q, k, v, **kw)
        ref = attention_ref(q, k, v, **kw)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("kw", [
    dict(kind="full"),
    dict(kind="swa", window=32),
    dict(kind="full", logit_softcap=30.0),
])
def test_attention_interpret_kernel_parity(kw):
    """The Pallas path (interpret on CPU) vs the pure-XLA reference, GQA
    shapes in the model's [B, T, H, D] layout — the flash_attention row of
    the dispatch tolerance table."""
    from repro.kernels.dispatch import attention as dispatch_attention
    from repro.models.attention import attention_ref
    q, k, v = _qkv_gqa(jax.random.PRNGKey(7))
    out = dispatch_attention(q, k, v, backend="interpret", **kw)
    ref = attention_ref(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_attention_dispatch_gates_unsupported_to_reference():
    """Shapes/masks the kernel doesn't cover fall back to the reference
    bitwise even when a kernel backend is forced: block-local masks,
    cross-length prefill (q_offset), and T not divisible by the block."""
    from repro.kernels.dispatch import attention as dispatch_attention
    from repro.models.attention import attention_ref
    q, k, v = _qkv_gqa(jax.random.PRNGKey(9))
    out = dispatch_attention(q, k, v, kind="chunked", window=16,
                             backend="interpret")
    ref = attention_ref(q, k, v, kind="chunked", window=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # ragged T: 192 % min(128, 192) != 0 -> reference
    q2, k2, v2 = _qkv_gqa(jax.random.PRNGKey(10), t=192)
    out2 = dispatch_attention(q2, k2, v2, backend="interpret")
    np.testing.assert_array_equal(np.asarray(out2),
                                  np.asarray(attention_ref(q2, k2, v2)))


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,d,chunk", [
    (1, 1, 64, 64, 64), (2, 2, 128, 64, 64), (1, 2, 256, 128, 64),
    (1, 1, 192, 64, 64),
])
def test_rwkv6_chunk_sweep(b, h, t, d, chunk):
    key = jax.random.PRNGKey(b * t + d)
    r, k, v = (0.5 * jax.random.normal(jax.random.fold_in(key, i),
                                       (b, h, t, d), jnp.float32)
               for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.5 * jax.random.normal(
        jax.random.fold_in(key, 3), (b, h, t, d))))
    u = 0.3 * jax.random.normal(jax.random.fold_in(key, 4), (h, d))
    s0 = 0.1 * jax.random.normal(jax.random.fold_in(key, 5), (b, h, d, d))
    o, sT = rwkv6_chunk(r, k, v, w, u, s0, chunk=chunk)
    oref, sref = rwkv6_chunk_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sref), rtol=3e-3, atol=3e-3)


def test_rwkv6_kernel_matches_model_path():
    """Kernel == the model's _wkv_chunk_scan (two independent implementations)."""
    from repro.models.rwkv import _wkv_chunk_scan
    key = jax.random.PRNGKey(11)
    b, h, t, d = 1, 2, 128, 64
    r, k, v = (0.5 * jax.random.normal(jax.random.fold_in(key, i),
                                       (b, t, h, d), jnp.float32)
               for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 3), (b, t, h, d))))
    u = 0.2 * jax.random.normal(jax.random.fold_in(key, 4), (h, d))
    s0 = jnp.zeros((b, h, d, d))
    o_model, s_model = _wkv_chunk_scan(r, k, v, w, u, s0)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    o_kern, s_kern = rwkv6_chunk(tr(r), tr(k), tr(v), tr(w), u, s0)
    np.testing.assert_allclose(np.asarray(tr(o_kern)), np.asarray(o_model),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(s_kern), np.asarray(s_model),
                               rtol=3e-3, atol=3e-3)
