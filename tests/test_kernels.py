"""Pallas kernels vs. pure-jnp oracles (interpret=True on CPU), with
shape/dtype sweeps and hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare image: fall back to seeded-random example cases
    HAVE_HYPOTHESIS = False

from repro.kernels.ops import (
    flash_attention,
    flash_attention_ref,
    gqa_flash_attention,
    masked_agg,
    masked_agg_pytree,
    masked_agg_ref,
    rwkv6_chunk,
    rwkv6_chunk_ref,
)


# ---------------------------------------------------------------------------
# masked_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,n,dtype", [
    (4, 128, jnp.float32), (8, 1000, jnp.float32), (16, 4097, jnp.bfloat16),
    (3, 64, jnp.float32), (100, 257, jnp.bfloat16),
])
def test_masked_agg_sweep(m, n, dtype):
    key = jax.random.PRNGKey(m * n)
    x = jax.random.normal(key, (m, n), jnp.float32).astype(dtype)
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (m,)) < 0.5)
    out = masked_agg(x, mask, block_n=256)
    ref = masked_agg_ref(x, mask)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=tol, atol=tol)


def _check_masked_agg(m, n, bits):
    mask = jnp.asarray([(bits >> i) & 1 for i in range(m)], jnp.float32)
    x = jnp.arange(m * n, dtype=jnp.float32).reshape(m, n)
    out = masked_agg(x, mask, block_n=128)
    ref = masked_agg_ref(x, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


if HAVE_HYPOTHESIS:

    @given(st.integers(1, 12), st.integers(1, 300), st.integers(0, 2 ** 12 - 1))
    @settings(max_examples=25, deadline=None)
    def test_masked_agg_property(m, n, bits):
        _check_masked_agg(m, n, bits)

else:
    _rng = np.random.default_rng(0)
    _CASES = (
        # edge cases hypothesis would shrink to: single row, empty/full masks
        [(1, 1, 0), (1, 1, 1), (12, 300, 0), (12, 300, 2 ** 12 - 1)]
        + [(int(_rng.integers(1, 13)), int(_rng.integers(1, 301)),
            int(_rng.integers(0, 2 ** 12))) for _ in range(21)]
    )

    @pytest.mark.parametrize("m,n,bits", _CASES)
    def test_masked_agg_property(m, n, bits):
        _check_masked_agg(m, n, bits)


def test_masked_agg_pytree_matches_engine():
    from repro.core import masked_mean
    key = jax.random.PRNGKey(7)
    clients = {"a": jax.random.normal(key, (6, 10, 3)),
               "b": jax.random.normal(jax.random.fold_in(key, 1), (6, 5))}
    mask = jnp.asarray([1, 1, 0, 1, 0, 0], jnp.float32)
    got = masked_agg_pytree(clients, mask)
    want = masked_mean(clients, mask)
    for k in clients:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,d,win,cap,dtype", [
    (2, 2, 256, 64, 0, 0.0, jnp.float32),
    (1, 3, 256, 128, 0, 0.0, jnp.float32),
    (1, 2, 256, 64, 128, 0.0, jnp.float32),     # sliding window
    (1, 2, 128, 64, 0, 50.0, jnp.float32),      # gemma softcap
    (1, 2, 256, 64, 0, 0.0, jnp.bfloat16),
])
def test_flash_attention_sweep(b, h, t, d, win, cap, dtype):
    key = jax.random.PRNGKey(t + d)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, h, t, d),
                                 jnp.float32).astype(dtype) for i in range(3))
    out = flash_attention(q, k, v, window=win, logit_softcap=cap)
    ref = flash_attention_ref(q, k, v, window=win, logit_softcap=cap)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_gqa_wrapper():
    key = jax.random.PRNGKey(3)
    b, t, h, kv, d = 1, 128, 4, 2, 64
    q = jax.random.normal(key, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, t, kv, d))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, t, kv, d))
    out = gqa_flash_attention(q, k, v)
    from repro.models.attention import attention
    ref = attention(q, k, v, kind="full", chunk=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# rwkv6
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,d,chunk", [
    (1, 1, 64, 64, 64), (2, 2, 128, 64, 64), (1, 2, 256, 128, 64),
    (1, 1, 192, 64, 64),
])
def test_rwkv6_chunk_sweep(b, h, t, d, chunk):
    key = jax.random.PRNGKey(b * t + d)
    r, k, v = (0.5 * jax.random.normal(jax.random.fold_in(key, i),
                                       (b, h, t, d), jnp.float32)
               for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.5 * jax.random.normal(
        jax.random.fold_in(key, 3), (b, h, t, d))))
    u = 0.3 * jax.random.normal(jax.random.fold_in(key, 4), (h, d))
    s0 = 0.1 * jax.random.normal(jax.random.fold_in(key, 5), (b, h, d, d))
    o, sT = rwkv6_chunk(r, k, v, w, u, s0, chunk=chunk)
    oref, sref = rwkv6_chunk_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sref), rtol=3e-3, atol=3e-3)


def test_rwkv6_kernel_matches_model_path():
    """Kernel == the model's _wkv_chunk_scan (two independent implementations)."""
    from repro.models.rwkv import _wkv_chunk_scan
    key = jax.random.PRNGKey(11)
    b, h, t, d = 1, 2, 128, 64
    r, k, v = (0.5 * jax.random.normal(jax.random.fold_in(key, i),
                                       (b, t, h, d), jnp.float32)
               for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 3), (b, t, h, d))))
    u = 0.2 * jax.random.normal(jax.random.fold_in(key, 4), (h, d))
    s0 = jnp.zeros((b, h, d, d))
    o_model, s_model = _wkv_chunk_scan(r, k, v, w, u, s0)
    tr = lambda x: x.transpose(0, 2, 1, 3)
    o_kern, s_kern = rwkv6_chunk(tr(r), tr(k), tr(v), tr(w), u, s0)
    np.testing.assert_allclose(np.asarray(tr(o_kern)), np.asarray(o_model),
                               rtol=3e-3, atol=3e-3)
    np.testing.assert_allclose(np.asarray(s_kern), np.asarray(s_model),
                               rtol=3e-3, atol=3e-3)
