"""Adaptive search driver: resumable rung segments, elastic re-batching,
and the successive-halving controller.

The two structural contracts the controller rests on are pinned here
directly against the segment runner:

- **resume is bit-for-bit**: k chained ``rung_rounds`` scans (carrying the
  ``(FedState, ds_state)`` pytree across dispatches) reproduce ONE
  uninterrupted ``k * rung_rounds`` program exactly — evals, losses, and
  every final-state leaf;
- **elastic re-pack is compile-free**: gathering an arbitrary survivor
  subset (duplicates included) out of a finished segment's carry and
  re-dispatching rides the already-compiled (init, scan) pair — zero new
  jit entries (the ``compiles_once`` pin).

Shapes follow tests/test_sweep.py (m=8, dim=16, hidden=16), where XLA CPU
keeps the batched reduction order stable, so equality is exact.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.experiments import SweepSpec
from repro.experiments.grid import (
    _runner_for,
    get_traced_task,
    make_cell_batch,
    segment_runner_for,
)
from repro.experiments.plots import export_curves
from repro.experiments.results import ResultsStore, cell_key
from repro.experiments.search import SearchSpec, run_search, sample_point

ALGO, SCHEME = "fedpbc", "bernoulli_ti"
SEEDS = (0, 1)
S = len(SEEDS)
SPEC = SweepSpec(algorithms=(ALGO,), schemes=(SCHEME,), seeds=SEEDS,
                 rounds=6, eval_every=3, num_clients=8, dim=16, hidden=16,
                 classes=10, n_per_class=60, n_train=480, per_client=24,
                 batch_size=4, local_steps=2)
METRICS = ("loss", "num_active")


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _gather(tree, rows):
    idx = jnp.asarray(rows)
    return jax.tree.map(lambda x: x[idx], tree)


def test_segment_resume_bit_for_bit():
    """Two chained 3-round segments == one uninterrupted 6-round program:
    evals, loss trajectories, AND every carried state leaf."""
    spec = dataclasses.replace(SPEC, lrs=(0.05, 0.1))
    task = get_traced_task(spec)
    fed = spec.cell_config(ALGO, SCHEME)
    batch = make_cell_batch(spec, fed, task)
    rseg = segment_runner_for(spec, ALGO, SCHEME, segment_rounds=3,
                              metric_keys=METRICS)
    assert rseg.carry_out
    carry = rseg.init(batch)
    evals, losses = [], []
    for _ in range(2):
        carry, out = rseg.step(carry, batch)
        evals.append(np.asarray(out["evals"]))
        losses.append(np.asarray(out["metrics"]["loss"]))
    full = _runner_for(spec, fed, task, METRICS)
    st_full, out_full = full(batch)
    np.testing.assert_array_equal(np.concatenate(evals, axis=1),
                                  np.asarray(out_full["evals"]))
    np.testing.assert_array_equal(np.concatenate(losses, axis=1),
                                  np.asarray(out_full["metrics"]["loss"]))
    # CPU backend: carry_out disables donation, so the final carry is live
    _assert_trees_equal(carry[0], st_full)


def test_elastic_repack_zero_new_compiles(compiles_once):
    """Re-packing a survivor subset (with a duplicate — refill-style) into
    a fresh full-width batch rides the SAME compiled (init, scan) pair, and
    each re-packed trajectory continues exactly as it would have unsliced."""
    spec = dataclasses.replace(SPEC, lrs=(0.02, 0.05, 0.1, 0.2))
    task = get_traced_task(spec)
    fed = spec.cell_config(ALGO, SCHEME)
    batch = make_cell_batch(spec, fed, task)
    # metric_keys=("loss",) gives this test its own runner-cache entry: the
    # other tests drive the METRICS runner at a different batch width, and
    # the compile pin here must count THIS test's dispatches only
    rseg = segment_runner_for(spec, ALGO, SCHEME, segment_rounds=3,
                              metric_keys=("loss",))
    carry1, out1 = rseg.step(rseg.init(batch), batch)

    # "survivors": point 2 kept, point 1 kept, plus point 2 duplicated
    # twice (padding) — an arbitrary re-pack order with repeats
    order = [2, 1, 2, 2]
    rows = np.concatenate([np.arange(p * S, (p + 1) * S) for p in order])
    carry2 = _gather(carry1, rows)
    batch2 = dataclasses.replace(
        batch,
        keys=_gather(batch.keys, rows), p_base=batch.p_base[rows],
        hparams=_gather(batch.hparams, rows),
        data=_gather(batch.data, rows), algo_id=batch.algo_id[rows])
    carry2, out2 = rseg.step(carry2, batch2)

    # the continuation of the unsliced batch, for comparison (CPU: no
    # donation, carry1 is still live after the dispatch above)
    _, out_ref = rseg.step(carry1, batch)
    for p_new, p_old in enumerate(order):
        np.testing.assert_array_equal(
            np.asarray(out2["evals"])[p_new * S:(p_new + 1) * S],
            np.asarray(out_ref["evals"])[p_old * S:(p_old + 1) * S])
    # ONE init + ONE scan entry across init, 3 steps, and the re-pack
    compiles_once(rseg.init_batch, rseg.scan_batch)


def test_run_search_prunes_and_persists(tmp_path, compiles_once):
    """End-to-end controller: a 4-candidate / eta=2 / 2-rung search prunes
    half the population at rung 1, spends measurably fewer device rounds
    than the exhaustive grid, persists every candidate with rung/budget
    provenance (distinct cell keys), and the mixed-length store exports."""
    base = SPEC
    search = SearchSpec(base=base, rung_rounds=3, eta=2, num_candidates=4,
                        batch_points=2, space=(("lr", ("log", 0.02, 0.3)),),
                        search_seed=0)
    store = ResultsStore(str(tmp_path / "search"))
    out = run_search(search, store=store, suite="t", metric_keys=METRICS)

    statuses = sorted(c.status for c in out.candidates)
    assert statuses == ["finished", "finished", "pruned", "pruned"]
    budgets = sorted(c.level * 3 for c in out.candidates)
    assert budgets == [3, 3, 6, 6]
    # wave 1: 2 batches x 2 points x 2 seeds x 3 rounds = 24; wave 2: the 2
    # survivors re-packed into ONE batch = 12. Exhaustive grid: 4*2*6 = 48.
    assert out.total_device_rounds == 36 < 4 * S * base.rounds
    assert out.waves == 2
    assert len(out.wave_log) == 2
    assert out.wave_log[-1]["device_rounds"] == 36
    assert out.best.status == "finished"
    assert out.best.last_eval == max(c.last_eval for c in out.candidates)
    if out.compile_entries["init"] is not None:
        assert out.compile_entries == {"init": 1, "scan": 1}
    rseg = segment_runner_for(base, ALGO, SCHEME, segment_rounds=3,
                              metric_keys=METRICS)
    compiles_once(rseg.init_batch, rseg.scan_batch)

    rows = store.records(suite="t")
    assert len(rows) == 4
    assert len({cell_key(r) for r in rows}) == 4      # no dedup collisions
    by_cid = {r["search"]["cid"]: r for r in rows}
    for c in out.candidates:
        r = by_cid[c.cid]
        assert r["search"]["budget_rounds"] == r["rounds"] == c.level * 3
        assert r["search"]["status"] == c.status
        assert r["search"]["rung_rounds"] == 3
        assert r["eval_rounds"] == [3 * (i + 1) for i in range(c.level)]
        arrs = store.load_arrays(r)
        assert arrs["test_acc"].shape == (S, c.level)
        assert arrs["loss"].shape == (S, c.level * 3)
        assert r["summary"]["test_acc"]["n"] == S
    # a pruned row and a finished row differ ONLY in the search coordinate
    # when their sampled points collide in every recorded hparam — build the
    # collision artificially to pin the key split
    pruned = next(r for r in rows if r["search"]["status"] == "pruned")
    fin = next(r for r in rows if r["search"]["status"] == "finished")
    clone = dict(fin, hparams=pruned["hparams"], rounds=pruned["rounds"],
                 eval_every=pruned["eval_every"], spec=pruned["spec"])
    assert cell_key(clone) != cell_key(pruned)

    # truncated + full-budget rows export side by side (would np.stack-crash
    # the old uniform-[E] pooling if they shared a curve)
    written = export_curves(store, str(tmp_path / "curves"), suite="t")
    assert len(written) == 8        # one acc + one loss CSV per candidate


def test_run_search_refill_fills_freed_slots():
    """refill=True tops partial batches up with freshly sampled level-0
    candidates instead of duplicate padding, bounded by max_candidates, and
    fresh candidates are ranked against their own budget level only."""
    search = SearchSpec(base=SPEC, rung_rounds=3, eta=2, num_candidates=3,
                        batch_points=2, refill=True, max_candidates=5,
                        space=(("lr", ("choice", (0.02, 0.05, 0.1, 0.2))),),
                        search_seed=1)
    out = run_search(search, metric_keys=METRICS)
    # wave 1 packs 3 alive into 2 batches; the half-empty second batch gets
    # ONE refill (4 total candidates; cap 5 never reached after wave 1
    # because later waves stay full or end)
    assert len(out.candidates) >= 4
    assert len(out.candidates) <= 5
    assert all(c.evals for c in out.candidates)       # everyone ran
    statuses = {c.status for c in out.candidates}
    assert statuses <= {"finished", "pruned"}
    assert any(c.status == "finished" for c in out.candidates)
    # every candidate's budget is a whole number of rungs within the cap
    for c in out.candidates:
        assert 1 <= c.level <= search.max_level


def test_search_target_stops_early():
    """A trivially low target stops the whole search at the first rung."""
    search = SearchSpec(base=SPEC, rung_rounds=3, eta=2, num_candidates=2,
                        space=(("lr", ("log", 0.05, 0.2)),), target=0.0)
    out = run_search(search, metric_keys=METRICS)
    assert out.target_hit
    assert out.waves == 1
    assert all(c.status in ("stopped", "finished") for c in out.candidates)
    assert out.device_rounds_to(0.0) == out.total_device_rounds


def test_sample_point_respects_space_and_defaults():
    rng = np.random.default_rng(0)
    search = SearchSpec(base=SPEC, rung_rounds=3,
                        space=(("lr", ("log", 0.01, 0.5)),
                               ("gamma", ("choice", (0.25, 0.75)))))
    for _ in range(16):
        pt = sample_point(rng, search)
        assert 0.01 <= pt["lr"] <= 0.5
        assert pt["gamma"] in (0.25, 0.75)
        assert pt["alpha"] == SPEC.alpha and pt["delta"] == SPEC.delta


@pytest.mark.parametrize("kw,msg", [
    (dict(rung_rounds=4), "must divide"),
    (dict(rung_rounds=3, eta=1), "eta"),
    (dict(rung_rounds=3, space=(("bogus", ("log", 0.1, 1.0)),)),
     "not a hyperparameter"),
    (dict(rung_rounds=3, space=(("lr", ("geometric", 0.1, 1.0)),)), "kind"),
    (dict(rung_rounds=3, space=(("lr", ("log", 1.0, 0.1)),)), "lo < hi"),
    (dict(rung_rounds=3, refill=True), "refill"),
    (dict(rung_rounds=3, points=()), "points"),
    (dict(rung_rounds=3, num_candidates=4, max_candidates=2),
     "max_candidates"),
    (dict(rung_rounds=3, points=({"lr": 0.1, "bogus": 1.0},)), "unknown"),
])
def test_searchspec_validation(kw, msg):
    with pytest.raises(ValueError, match=msg):
        SearchSpec(base=SPEC, **kw)


def test_searchspec_rejects_multi_cell_base():
    with pytest.raises(ValueError, match="one"):
        SearchSpec(base=dataclasses.replace(
            SPEC, algorithms=("fedpbc", "fedavg")), rung_rounds=3)
    with pytest.raises(ValueError, match="swept axes"):
        SearchSpec(base=dataclasses.replace(SPEC, lrs=(0.1, 0.2)),
                   rung_rounds=3)
