"""Multi-device sharding of the sweep engine's (point x seed) batch axis.

The acceptance guarantee: sharding the flattened batch axis of
``make_batched_run_rounds`` over a ``("batch",)`` mesh — including padding B
up to a device multiple — changes NOTHING per trajectory. Every result leaf
of the sharded path must be bit-for-bit equal to the single-device path, and
padding rows must never reach a ``CellResult`` or a ``ResultsStore`` row.

The multi-device tests need more than one device; CI provides 8 forced host
devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (they skip
on a plain single-device run, where the auto path is single-device anyway).
The wrapper-machinery tests (padding, mesh resolution, the explicit
single-device mesh) run everywhere.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.experiments import SweepSpec, ResultsStore, run_sweep
from repro.experiments.grid import (
    _RUNNER_CACHE,
    _SHARDED_BATCH_CACHE,
    _runner_for,
    get_traced_task,
    make_cell_batch,
    run_cell_batch,
)
from repro.experiments.shard import (
    pad_batch,
    resolve_batch_mesh,
    run_sharded,
    shard_batch,
)
from repro.launch.mesh import make_batch_mesh

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SEEDS = (0, 1, 2)
# B = 2 lrs x 3 seeds = 6 trajectories: NOT divisible by 8 devices, so the
# multi-device tests exercise the padding path end to end
BASE = SweepSpec(seeds=SEEDS, num_clients=8, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=4, local_steps=3, rounds=5, eval_every=2,
                 lrs=(0.05, 0.1))
METRIC_KEYS = ("loss", "num_active")


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_pad_batch_repeats_last_trajectory():
    task = get_traced_task(BASE)
    fed = BASE.cell_config("fedpbc", "bernoulli_tv")
    batch = make_cell_batch(BASE, fed, task)
    B = batch.batch_size
    assert B == 6

    same, b_real = pad_batch(batch, 3)          # 3 | 6: no-op, same object
    assert same is batch and b_real == B

    padded, b_real = pad_batch(batch, 4)        # 6 -> 8
    assert b_real == B and padded.batch_size == 8
    for x, p in zip(jax.tree.leaves((batch.keys, batch.p_base, batch.hparams,
                                     batch.data)),
                    jax.tree.leaves((padded.keys, padded.p_base,
                                     padded.hparams, padded.data))):
        np.testing.assert_array_equal(np.asarray(p[:B]), np.asarray(x))
        for row in np.asarray(p[B:]):
            np.testing.assert_array_equal(row, np.asarray(x[-1]))
    # shared is untouched (it has no batch axis to pad)
    _assert_trees_equal(padded.shared, batch.shared)


def test_resolve_batch_mesh_semantics():
    assert resolve_batch_mesh(None) is None
    assert resolve_batch_mesh(None, devices=jax.devices()) is None
    # an explicit device list opts in, even with a single device
    mesh1 = resolve_batch_mesh("auto", devices=jax.devices()[:1])
    assert mesh1.axis_names == ("batch",) and mesh1.devices.size == 1
    auto = resolve_batch_mesh()
    if N_DEV > 1:
        assert auto is not None and auto.devices.size == N_DEV
    else:
        assert auto is None
    explicit = make_batch_mesh()
    assert resolve_batch_mesh(explicit) is explicit
    with pytest.raises(ValueError, match="'batch' axis"):
        from repro.launch.mesh import make_host_mesh
        resolve_batch_mesh(make_host_mesh())
    with pytest.raises(ValueError, match="mesh must be"):
        resolve_batch_mesh("everywhere")


@multi_device
def test_shard_batch_requires_divisible_batch():
    task = get_traced_task(BASE)
    fed = BASE.cell_config("fedpbc", "bernoulli_tv")
    batch = make_cell_batch(BASE, fed, task)    # B = 6
    mesh = make_batch_mesh()
    if batch.batch_size % mesh.devices.size == 0:
        pytest.skip("device count divides B here")
    with pytest.raises(ValueError, match="not divisible"):
        shard_batch(batch, mesh)


def test_explicit_single_device_mesh_matches_plain_path():
    """The pad/shard/slice wrapper itself must be a numeric no-op: an
    explicit 1-device mesh (wrapper engaged) equals the plain path bitwise.
    Runs in every environment, multi-device or not."""
    plain = run_cell_batch(BASE, "fedpbc", "bernoulli_tv",
                           metric_keys=METRIC_KEYS, mesh=None)
    wrapped = run_cell_batch(BASE, "fedpbc", "bernoulli_tv",
                             metric_keys=METRIC_KEYS,
                             devices=jax.devices()[:1])
    assert len(plain) == len(wrapped) == 2
    for a, b in zip(plain, wrapped):
        assert a.hparams == b.hparams
        np.testing.assert_array_equal(a.test_acc, b.test_acc)
        np.testing.assert_array_equal(a.train_acc, b.train_acc)
        np.testing.assert_array_equal(a.loss, b.loss)
        np.testing.assert_array_equal(a.num_active, b.num_active)


def test_sharded_batch_cache_is_period_independent():
    """Cells differing only in a ``period`` fed_override must reuse ONE
    committed copy of the heavy batch arrays (the cache key excludes fed);
    only the tiny [B] period vector is rebuilt — and it must still be wired,
    i.e. the two periods produce different activation trajectories."""
    spec20 = dataclasses.replace(BASE, fed_overrides=(("period", 20),))
    spec40 = dataclasses.replace(spec20, fed_overrides=(("period", 40),))
    one_dev = jax.devices()[:1]
    n0 = len(_SHARDED_BATCH_CACHE)
    c20 = run_cell_batch(spec20, "fedpbc", "bernoulli_tv",
                         metric_keys=METRIC_KEYS, devices=one_dev)
    c40 = run_cell_batch(spec40, "fedpbc", "bernoulli_tv",
                         metric_keys=METRIC_KEYS, devices=one_dev)
    assert len(_SHARDED_BATCH_CACHE) <= n0 + 1
    assert not np.array_equal(np.concatenate([c.num_active for c in c20]),
                              np.concatenate([c.num_active for c in c40]))


@multi_device
def test_sharded_runner_bit_for_bit_with_padding():
    """8 forced host devices, B = 6 (padded to 8): every leaf of (states,
    out) from the sharded path equals the single-device run of the SAME
    cached runner, per trajectory."""
    task = get_traced_task(BASE)
    fed = BASE.cell_config("fedpbc", "bernoulli_tv")
    runner = _runner_for(BASE, fed, task, METRIC_KEYS)
    n_runners = len(_RUNNER_CACHE)
    batch = make_cell_batch(BASE, fed, task)
    mesh = resolve_batch_mesh()
    assert mesh.devices.size == N_DEV and batch.batch_size % N_DEV != 0

    ref_states, ref_out = runner(batch)                 # single-device
    sh_states, sh_out = run_sharded(runner, batch, mesh)
    _assert_trees_equal((sh_states, sh_out), (ref_states, ref_out))
    # both paths share ONE runner — the executor cache key is placement-free
    assert len(_RUNNER_CACHE) == n_runners
    assert _runner_for(BASE, fed, task, METRIC_KEYS) is runner


@multi_device
def test_sharded_outputs_live_on_all_devices():
    """The sharded run must actually split the batch axis: result leaves are
    laid out across every mesh device, not silently replicated on one."""
    task = get_traced_task(BASE)
    fed = BASE.cell_config("fedpbc", "bernoulli_tv")
    runner = _runner_for(BASE, fed, task, METRIC_KEYS)
    batch, _ = pad_batch(make_cell_batch(BASE, fed, task), N_DEV)
    mesh = resolve_batch_mesh()
    states, out = runner(shard_batch(batch, mesh))
    loss = out["metrics"]["loss"]
    assert len(loss.sharding.device_set) == N_DEV
    shard_rows = {s.index[0].start for s in loss.addressable_shards}
    assert len(shard_rows) == N_DEV                     # distinct batch slices
    assert len(jax.tree.leaves(states.server)[0].sharding.device_set) == N_DEV


@multi_device
def test_run_cell_batch_auto_shards_and_matches():
    """The default (auto) path picks the sharded runner when >1 device is
    visible and returns per-point results identical to mesh=None."""
    plain = run_cell_batch(BASE, "fedpbc", "bernoulli_tv",
                           metric_keys=METRIC_KEYS, mesh=None)
    auto = run_cell_batch(BASE, "fedpbc", "bernoulli_tv",
                          metric_keys=METRIC_KEYS)
    for a, b in zip(plain, auto):
        assert a.hparams == b.hparams
        np.testing.assert_array_equal(a.test_acc, b.test_acc)
        np.testing.assert_array_equal(a.train_acc, b.train_acc)
        np.testing.assert_array_equal(a.loss, b.loss)
        np.testing.assert_array_equal(a.num_active, b.num_active)


@multi_device
def test_padded_sharded_sweep_writes_exactly_b_real_rows(tmp_path):
    """End to end through the store: a padded-B sharded sweep appends exactly
    one row per hyperparameter point with [S]-seed arrays — the two padding
    trajectories (6 -> 8) never leak into any row's payload."""
    store = ResultsStore(str(tmp_path / "sweeps"))
    n_sharded = len(_SHARDED_BATCH_CACHE)
    cells = run_sweep(BASE, store=store, suite="shard-smoke",
                      metric_keys=METRIC_KEYS)
    # one padded+committed batch serves every cell of the sweep (the cells
    # share seeds/points/period, so the device transfer is memoized)
    assert len(_SHARDED_BATCH_CACHE) <= n_sharded + 1
    points = BASE.hparam_points()
    assert len(cells) == len(points) * len(BASE.algorithms) * len(BASE.schemes)
    rows = store.records(suite="shard-smoke")
    assert len(rows) == len(cells)
    for row, cell in zip(rows, cells):
        arrays = store.load_arrays(row)
        assert arrays["test_acc"].shape == (len(SEEDS), 3)
        assert arrays["loss"].shape == (len(SEEDS), BASE.rounds)
        np.testing.assert_array_equal(arrays["test_acc"], cell.test_acc)
        # padding repeats the LAST real trajectory; if a padded row leaked,
        # it would duplicate seed -1's trajectory — all seeds stay distinct
        assert len({a.tobytes() for a in arrays["test_acc"]}) == len(SEEDS)
