"""Proposition 1 / Eq. (3): FedAvg's biased fixed point."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederationConfig
from repro.core import init_fed_state, make_algorithm, make_link_process, make_round_fn
from repro.core.bias import (
    fedavg_client_weights,
    fedavg_fixed_point,
    fedavg_fixed_point_series,
    two_client_fixed_point,
)
from repro.optim import sgd


def test_series_matches_enumeration():
    """The paper's inclusion-exclusion series == direct E[X_i/sum X] enumeration."""
    rng = np.random.default_rng(0)
    for m in (2, 3, 5, 7):
        p = rng.uniform(0.05, 0.95, size=m)
        u = rng.normal(size=(m, 3))
        np.testing.assert_allclose(
            fedavg_fixed_point(p, u), fedavg_fixed_point_series(p, u), rtol=1e-9)


def test_weights_sum_to_one():
    rng = np.random.default_rng(1)
    for m in (2, 4, 6):
        p = rng.uniform(0.05, 0.95, size=m)
        w = fedavg_client_weights(p)
        assert abs(w.sum() - 1.0) < 1e-9
        assert (w > 0).all()


def test_fig2_two_client_example():
    """Fig. 2: u1=0, u2=100, p1=0.5 -> E[x] = 150 p2 / (p2 + 1)."""
    for p2 in (0.1, 0.3, 0.5, 0.9):
        expected = 150.0 * p2 / (p2 + 1.0)
        got = two_client_fixed_point(0.0, 100.0, 0.5, p2)
        np.testing.assert_allclose(got, expected, rtol=1e-9)
        np.testing.assert_allclose(
            fedavg_fixed_point(np.array([0.5, p2]),
                               np.array([[0.0], [100.0]]))[0],
            expected, rtol=1e-9)
    # uniform p -> unbiased
    np.testing.assert_allclose(two_client_fixed_point(0.0, 100.0, 0.5, 0.5), 50.0)


def test_uniform_p_unbiased():
    rng = np.random.default_rng(2)
    m = 6
    u = rng.normal(size=(m, 4))
    fp = fedavg_fixed_point(np.full(m, 0.3), u)
    np.testing.assert_allclose(fp, u.mean(0), rtol=1e-8)


@pytest.mark.slow
def test_fedavg_simulation_converges_to_eq3():
    """Monte-Carlo FedAvg on quadratics lands on Eq. (3), not on x*."""
    m, d, s = 6, 4, 30
    rng = np.random.default_rng(3)
    u = jnp.asarray(rng.normal(size=(m, d)))
    p = jnp.asarray(np.linspace(0.15, 0.9, m))
    fed = FederationConfig(algorithm="fedavg", num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    loss = lambda params, batch: 0.5 * jnp.sum((params["x"] - batch["u"]) ** 2)
    opt = sgd(0.02)
    round_fn = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.zeros(d)}, fed, algo, link, opt)
    batches = {"u": jnp.broadcast_to(u[:, None], (m, s, d))}
    tail = []
    for t in range(3000):
        st, _ = round_fn(st, batches)
        if t > 2000:
            tail.append(np.asarray(st.server["x"]))
    avg_tail = np.mean(tail, 0)
    eq3 = fedavg_fixed_point(np.asarray(p), np.asarray(u))
    x_star = np.asarray(u).mean(0)
    # the simulated mean is far closer to Eq. (3) than to the true optimum
    assert np.linalg.norm(avg_tail - eq3) < 0.35 * np.linalg.norm(avg_tail - x_star)
