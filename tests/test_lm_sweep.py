"""The LM workload on the 2-D ("batch", "model") mesh.

Acceptance pins for the sharded-LM sweep path:

1. A tiny-config LM family sweep (fedpbc/fedavg/fedavg_all/fedavg_known_p,
   swept lrs, 2 seeds) is bit-for-bit equal between ``mesh=None`` and the
   2-D mesh on 8 forced host devices — including host-side train accuracy
   and the in-scan evals (CI runs this file under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; most tests skip
   below 8 devices).
2. Zero extra jit entries: the whole family sweep on the 2-D path compiles
   exactly one (init, scan) pair (the compile-counter contract of
   ``test_kernel_sweep.py``).
3. Cohort mode (``cohort_size=C``, stateless clients) rides the same 2-D
   path bit-for-bit.
4. ``run_sharded_2d`` pads a ragged B to the mesh's batch axis and slices
   the padding off on the host; it rejects runners not built for the mesh.
5. ``spec_for_shape`` on a model-axis mesh covers every smollm-135m weight
   leaf, and the pad-or-replicate fallback shards large uneven leaves
   instead of silently replicating them (satellite of the same PR).
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import algo_family
from repro.experiments import SweepSpec, run_sweep
from repro.experiments.grid import (
    _runner_for,
    get_traced_task,
    make_cell_batch,
)
from repro.experiments.shard import run_sharded_2d
from repro.launch.mesh import make_2d_mesh

N_DEV = len(jax.devices())
eight_devices = pytest.mark.skipif(
    N_DEV < 8,
    reason="needs 8 forced host devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

FAMILY = algo_family("fedavg")   # fedpbc/fedavg/fedavg_all/fedavg_known_p
METRIC_KEYS = ("loss", "num_active")

LM = SweepSpec(algorithms=FAMILY, schemes=("bernoulli_ti",), seeds=(0, 1),
               rounds=3, eval_every=2, num_clients=4, local_steps=2,
               batch_size=1, per_client=8, lrs=(0.05, 0.1),
               task="lm", lm_d_model=32, lm_layers=1, lm_seq=16, classes=4,
               lm_n_seqs=64, lm_n_test=16)


def _cells_equal(a, b):
    assert (a.algo, a.scheme, a.hparams, a.strategy) == \
        (b.algo, b.scheme, b.hparams, b.strategy)
    np.testing.assert_array_equal(a.test_acc, b.test_acc)
    np.testing.assert_array_equal(a.train_acc, b.train_acc)
    np.testing.assert_array_equal(a.loss, b.loss)
    np.testing.assert_array_equal(a.num_active, b.num_active)


@functools.lru_cache(maxsize=None)
def _family_sweeps():
    """One single-device + one 2-D-mesh run of the LM family sweep (shared
    by the bitwise and compile-counter tests)."""
    mesh = make_2d_mesh(4, 2, jax.devices()[:8])
    plain = run_sweep(LM, metric_keys=METRIC_KEYS, mesh=None)
    sharded = run_sweep(LM, metric_keys=METRIC_KEYS, mesh=mesh)
    return plain, sharded, mesh


def test_lm_sweep_runs_single_device():
    """The LM task is a first-class sweep workload: rows come back in grid
    order, the algorithm axis is live (members diverge), losses are
    finite."""
    spec = dataclasses.replace(LM, algorithms=("fedpbc", "fedavg"),
                               seeds=(0,), lrs=(0.1,))
    cells = run_sweep(spec, metric_keys=METRIC_KEYS, mesh=None)
    assert [c.algo for c in cells] == ["fedpbc", "fedavg"]
    for c in cells:
        assert c.test_acc.shape == (1, 2)     # evals at rounds 2 and 3
        assert np.isfinite(c.loss).all()
    assert cells[0].loss.tobytes() != cells[1].loss.tobytes()


@eight_devices
def test_lm_family_sweep_2d_bit_for_bit():
    """All 4 family members x 2 lrs x 2 seeds: every row of the 2-D-mesh
    sweep equals the single-device sweep bitwise."""
    plain, sharded, _ = _family_sweeps()
    assert len(plain) == len(FAMILY) * len(LM.lrs)
    for a, b in zip(plain, sharded):
        _cells_equal(a, b)


@eight_devices
def test_lm_sweep_2d_zero_extra_jit_entries(compiles_once):
    """The whole 4-member family sweep on the 2-D path compiles exactly one
    (init, scan) pair: swept lrs, seeds and the algorithm axis all ride the
    same program."""
    _, _, mesh = _family_sweeps()
    fed = LM.cell_config(FAMILY[0], "bernoulli_ti")
    runner = _runner_for(LM, fed, get_traced_task(LM), METRIC_KEYS,
                         shard_mesh=mesh)
    assert runner.shard_mesh == mesh
    compiles_once(runner.init_batch, runner.scan_batch)


@eight_devices
def test_lm_cohort_2d_bit_for_bit():
    """Cohort mode (stateless clients, per-round C-subsample — the
    cross-device scale path) on the 2-D mesh equals its single-device
    program bitwise."""
    spec = dataclasses.replace(LM, algorithms=("fedpbc", "fedavg"),
                               num_clients=8, cohort_size=2, seeds=(0,),
                               lrs=(0.1,))
    plain = run_sweep(spec, metric_keys=METRIC_KEYS, mesh=None)
    mesh = make_2d_mesh(4, 2, jax.devices()[:8])
    sharded = run_sweep(spec, metric_keys=METRIC_KEYS, mesh=mesh)
    assert len(plain) == 2
    for a, b in zip(plain, sharded):
        _cells_equal(a, b)


@eight_devices
def test_run_sharded_2d_pads_ragged_batch():
    """B = 3 trajectories on a batch axis of 4: padding rows are sliced off
    on the host and the result equals the unsharded runner bitwise."""
    spec = dataclasses.replace(LM, seeds=(0,), lrs=(0.1,))
    task = get_traced_task(spec)
    fed = spec.cell_config(FAMILY[0], "bernoulli_ti")
    mesh = make_2d_mesh(4, 2, jax.devices()[:8])
    batch = make_cell_batch(spec, fed, task, algos=FAMILY[:3])
    assert batch.batch_size == 3
    r2d = _runner_for(spec, fed, task, METRIC_KEYS, shard_mesh=mesh)
    plain = _runner_for(spec, fed, task, METRIC_KEYS)
    got = run_sharded_2d(r2d, batch, mesh)
    want = plain(batch)
    for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # a runner built without the mesh is rejected up front
    with pytest.raises(ValueError, match="not built for this mesh"):
        run_sharded_2d(plain, batch, mesh)


# ---------------------------------------------------------------------------
# spec_for_shape over LM parameter shapes (the pad-or-replicate fallback)
# ---------------------------------------------------------------------------


@eight_devices
def test_spec_for_shape_covers_smollm_weights():
    """Every >=2-D weight leaf of the real smollm-135m init gets a "model"
    entry on an 8-way model mesh (its dims all divide 8 — nothing should
    silently replicate)."""
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.models.model import init_params
    from repro.sharding.specs import spec_for_shape

    mesh = Mesh(np.asarray(jax.devices()[:8]), ("model",))
    cfg = get_config("smollm-135m")
    shapes = jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))
    leaves = jax.tree.leaves(shapes)
    assert leaves, "smollm init produced no leaves"
    for leaf in leaves:
        spec = spec_for_shape(leaf.shape, mesh)
        assert len(spec) == len(leaf.shape)
        if leaf.ndim >= 2:
            assert "model" in spec, (leaf.shape, spec)


@eight_devices
def test_spec_for_shape_uneven_fallback():
    """No dim divides the 8-way model axis: the largest dim >= the axis
    size is sharded anyway (GSPMD pads the ragged shard) instead of
    replicating the whole leaf; dims smaller than the axis replicate."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.sharding.specs import spec_for_shape

    mesh = Mesh(np.asarray(jax.devices()[:8]), ("model",))
    # 577 % 8 == 1535 % 8 != 0 -> fallback shards the LARGER dim
    assert spec_for_shape((577, 1535), mesh) == P(None, "model")
    assert spec_for_shape((49153, 577), mesh) == P("model", None)
    # divisible dims keep the exact-shard preference (last divisible dim)
    assert spec_for_shape((577, 1536), mesh) == P(None, "model")
    # everything below the axis size replicates
    assert spec_for_shape((7,), mesh) == P(None)
    assert spec_for_shape((3, 5), mesh) == P(None, None)
    # uneven specs are legal through with_sharding_constraint (NOT
    # device_put / out_shardings): a jitted constraint commits the layout
    sh = NamedSharding(mesh, spec_for_shape((577, 1535), mesh))
    x = jnp.ones((577, 1535))
    y = jax.jit(lambda a: jax.lax.with_sharding_constraint(a, sh) * 1.0)(x)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
