"""Unreliable-uplink schemes (paper §7.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederationConfig
from repro.core import build_base_probs, make_link_process, p_of_t


def _empirical_rates(link, m, T=2000, seed=0):
    state = link.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    counts = np.zeros(m)
    for t in range(T):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        counts += np.asarray(active)
    return counts / T


def test_base_prob_construction():
    """Eq. (9): p_i = <r, nu_i> in (0, 1], clipped at delta."""
    p, nu, r = build_base_probs(jax.random.PRNGKey(0), 100, 10,
                                alpha=0.1, sigma0=10.0, delta=0.02)
    assert p.shape == (100,)
    assert (p >= 0.02 - 1e-9).all() and (p <= 1.0).all()
    np.testing.assert_allclose(np.asarray(r).sum(), 1.0, rtol=1e-6)
    # heavy-tailed r (sigma0=10): most mass on few classes (paper Fig. 4a)
    assert np.sort(np.asarray(r))[-2:].sum() > 0.5


def test_p_of_t_range():
    p = jnp.asarray([0.5, 0.9])
    for t in range(80):
        pt = p_of_t(p, jnp.float32(t), gamma=0.5, period=40)
        assert (pt >= 0).all() and (pt <= 1).all()
    # sin completes a cycle: p back to start
    np.testing.assert_allclose(p_of_t(p, jnp.float32(0), gamma=0.5, period=40),
                               p_of_t(p, jnp.float32(40), gamma=0.5, period=40),
                               rtol=1e-5)


def test_bernoulli_rate():
    m = 8
    p = jnp.linspace(0.1, 0.9, m)
    fed = FederationConfig(num_clients=m, scheme="bernoulli")
    rates = _empirical_rates(make_link_process(p, fed), m)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.05)


def test_markov_stationary_rate():
    """Table 3 transitions are built to have stationary distribution p_i."""
    m = 6
    p = jnp.asarray([0.1, 0.25, 0.4, 0.55, 0.7, 0.9])
    fed = FederationConfig(num_clients=m, scheme="markov")
    rates = _empirical_rates(make_link_process(p, fed), m, T=6000)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.08)


def _markov_ensemble_fractions(link, m, T, seed=0):
    """Per-round empirical ON-fraction over an ensemble of m iid chains."""
    state = link.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    fracs = []
    for t in range(T):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        fracs.append(float(np.mean(np.asarray(active))))
    return np.asarray(fracs)


def _markov_transitions(p):
    """Table 3 rates (q = ON->OFF, q* = OFF->ON), numpy mirror of the
    implementation's ``transitions``."""
    p = np.clip(p, 1e-4, 1 - 1e-4)
    cond = 0.05 * (1.0 - p) <= p
    q_star = np.where(cond, 0.05, p / (1.0 - p))
    q = np.where(cond, 0.05 * (1.0 - p) / p, 1.0)
    return q, q_star


def test_markov_homogeneous_marginal_pinned_every_round():
    """Time-index audit, homogeneous half: the mask for round t is the
    post-transition state X_t with X_{-1} ~ Bernoulli(p_base), so the
    ensemble marginal equals p_base at EVERY round (the Table 3 rates have
    stationary distribution p_base and init starts the chain there) — an
    off-by-one that returned the pre-transition state would also pass this,
    which is why the non-homogeneous test below pins the exact recursion."""
    m, T, p0 = 4000, 48, 0.3
    fed = FederationConfig(num_clients=m, scheme="markov", time_varying=False)
    fracs = _markov_ensemble_fractions(
        make_link_process(jnp.full((m,), p0), fed), m, T)
    sigma = np.sqrt(p0 * (1 - p0) / m)
    assert np.abs(fracs - p0).max() < 5 * sigma


def test_markov_nonhom_tracks_p_of_t_over_a_period():
    """Time-index audit, non-homogeneous half (Eq. 9 dynamics): the round-t
    mask is driven by rates derived from p_i^t, so the ensemble ON-fraction
    must (a) match the exact recursion mu_t = (1 - q_t - q*_t) mu_{t-1} +
    q*_t with (q_t, q*_t) = transitions(p_of_t(t)) and mu_{-1} = p_base —
    this pins the indexing exactly (shifting the recursion by one round
    breaks it) — and (b) track p_i^t over a period up to the chain's mixing
    lag: strong correlation and matching time-averages, not per-round
    equality (the chain has memory; its marginal lags a fast sine)."""
    m, T = 4000, 64
    p0, gamma, period = 0.3, 0.6, 16
    fed = FederationConfig(num_clients=m, scheme="markov", time_varying=True,
                           gamma=gamma, period=period)
    fracs = _markov_ensemble_fractions(
        make_link_process(jnp.full((m,), p0), fed), m, T)

    mu, mus, pts = p0, [], []
    for t in range(T):
        p_t = float(p_of_t(jnp.float32(p0), jnp.float32(t), gamma=gamma,
                           period=period))
        q, q_star = _markov_transitions(p_t)
        mu = mu * (1.0 - q - q_star) + q_star
        mus.append(mu)
        pts.append(p_t)
    mus, pts = np.asarray(mus), np.asarray(pts)

    # (a) exact recursion, round by round (ensemble noise only)
    assert np.abs(fracs - mus).max() < 5 * np.sqrt(0.25 / m)
    # an off-by-one (recursion driven by p^{t-1} instead of p^t) must fail (a)
    mu, shifted = p0, []
    for t in range(T):
        q, q_star = _markov_transitions(pts[t - 1] if t else p0)
        mu = mu * (1.0 - q - q_star) + q_star
        shifted.append(mu)
    assert np.abs(np.asarray(shifted) - mus).max() > 10 * np.sqrt(0.25 / m)
    # (b) tracking over full periods, past the initial transient
    steady = slice(period, None)
    corr = np.corrcoef(fracs[steady], pts[steady])[0, 1]
    assert corr > 0.8
    assert abs(fracs[steady].mean() - pts[steady].mean()) < 0.05


@pytest.mark.parametrize("reset", [False, True])
def test_cyclic_duty_cycle(reset):
    m = 5
    p = jnp.asarray([0.2, 0.4, 0.5, 0.6, 0.8])
    fed = FederationConfig(num_clients=m, scheme="cyclic", cyclic_length=50,
                           cyclic_reset=reset)
    rates = _empirical_rates(make_link_process(p, fed), m, T=4000)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.07)


def test_cyclic_no_reset_is_periodic():
    """Without reset the on/off pattern repeats exactly each cycle."""
    m, L = 4, 40
    p = jnp.asarray([0.3, 0.5, 0.7, 0.9])
    fed = FederationConfig(num_clients=m, scheme="cyclic", cyclic_length=L)
    link = make_link_process(p, fed)
    state = link.init(jax.random.PRNGKey(5))
    key = jax.random.PRNGKey(6)
    trace = []
    for t in range(3 * L):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        trace.append(np.asarray(active))
    trace = np.stack(trace)
    np.testing.assert_array_equal(trace[:L], trace[L:2 * L])
    np.testing.assert_array_equal(trace[:L], trace[2 * L:3 * L])
