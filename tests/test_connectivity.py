"""Unreliable-uplink schemes (paper §7.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederationConfig
from repro.core import build_base_probs, make_link_process, p_of_t


def _empirical_rates(link, m, T=2000, seed=0):
    state = link.init(jax.random.PRNGKey(seed))
    key = jax.random.PRNGKey(seed + 1)
    counts = np.zeros(m)
    for t in range(T):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        counts += np.asarray(active)
    return counts / T


def test_base_prob_construction():
    """Eq. (9): p_i = <r, nu_i> in (0, 1], clipped at delta."""
    p, nu, r = build_base_probs(jax.random.PRNGKey(0), 100, 10,
                                alpha=0.1, sigma0=10.0, delta=0.02)
    assert p.shape == (100,)
    assert (p >= 0.02 - 1e-9).all() and (p <= 1.0).all()
    np.testing.assert_allclose(np.asarray(r).sum(), 1.0, rtol=1e-6)
    # heavy-tailed r (sigma0=10): most mass on few classes (paper Fig. 4a)
    assert np.sort(np.asarray(r))[-2:].sum() > 0.5


def test_p_of_t_range():
    p = jnp.asarray([0.5, 0.9])
    for t in range(80):
        pt = p_of_t(p, jnp.float32(t), gamma=0.5, period=40)
        assert (pt >= 0).all() and (pt <= 1).all()
    # sin completes a cycle: p back to start
    np.testing.assert_allclose(p_of_t(p, jnp.float32(0), gamma=0.5, period=40),
                               p_of_t(p, jnp.float32(40), gamma=0.5, period=40),
                               rtol=1e-5)


def test_bernoulli_rate():
    m = 8
    p = jnp.linspace(0.1, 0.9, m)
    fed = FederationConfig(num_clients=m, scheme="bernoulli")
    rates = _empirical_rates(make_link_process(p, fed), m)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.05)


def test_markov_stationary_rate():
    """Table 3 transitions are built to have stationary distribution p_i."""
    m = 6
    p = jnp.asarray([0.1, 0.25, 0.4, 0.55, 0.7, 0.9])
    fed = FederationConfig(num_clients=m, scheme="markov")
    rates = _empirical_rates(make_link_process(p, fed), m, T=6000)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.08)


@pytest.mark.parametrize("reset", [False, True])
def test_cyclic_duty_cycle(reset):
    m = 5
    p = jnp.asarray([0.2, 0.4, 0.5, 0.6, 0.8])
    fed = FederationConfig(num_clients=m, scheme="cyclic", cyclic_length=50,
                           cyclic_reset=reset)
    rates = _empirical_rates(make_link_process(p, fed), m, T=4000)
    np.testing.assert_allclose(rates, np.asarray(p), atol=0.07)


def test_cyclic_no_reset_is_periodic():
    """Without reset the on/off pattern repeats exactly each cycle."""
    m, L = 4, 40
    p = jnp.asarray([0.3, 0.5, 0.7, 0.9])
    fed = FederationConfig(num_clients=m, scheme="cyclic", cyclic_length=L)
    link = make_link_process(p, fed)
    state = link.init(jax.random.PRNGKey(5))
    key = jax.random.PRNGKey(6)
    trace = []
    for t in range(3 * L):
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, jnp.int32(t), k)
        trace.append(np.asarray(active))
    trace = np.stack(trace)
    np.testing.assert_array_equal(trace[:L], trace[L:2 * L])
    np.testing.assert_array_equal(trace[:L], trace[2 * L:3 * L])
