import os

# Smoke tests and benches must see ONE device (the dry-run sets its own
# 512-device flag in repro.launch.dryrun, which is never imported here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses

import jax
import pytest

from repro.configs import get_config, reduced


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def reduced_f32(arch: str, **kw):
    cfg = reduced(get_config(arch), **kw)
    return dataclasses.replace(cfg, dtype="float32")
