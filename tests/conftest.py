import os

# Smoke tests and benches must see ONE device (the dry-run sets its own
# 512-device flag in repro.launch.dryrun, which is never imported here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses

import jax
import pytest

from repro.configs import get_config, reduced


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def compiles_once():
    """The suite-wide compile-counter pin: every runner stage passed in
    must hold exactly ONE jit cache entry — the ROADMAP contract that all
    swept axes (hparams, seeds, algo_id, strategies) ride traced inputs.
    No-ops gracefully where jit cache introspection is unavailable, like
    the per-file ``hasattr(fn, "_cache_size")`` guards it replaces."""
    from repro.analysis.sanitize import assert_no_new_compiles

    def check(*fns, expect_total=1):
        assert_no_new_compiles(*fns, expect_total=expect_total)

    return check


def reduced_f32(arch: str, **kw):
    cfg = reduced(get_config(arch), **kw)
    return dataclasses.replace(cfg, dtype="float32")
