"""Cross-device scale subsystem (``repro.scale``).

Acceptance guarantees:

1. Cohort mode keeps per-round client tensors at O(C): ``FedState`` holds
   no ``[m, ...]`` client-parameter or optimizer leaf, and a cohort round
   at m in the tens of thousands compiles and runs on CPU.
2. ``source.sample_cohort`` over the full-population cohort
   ``arange(m)`` IS the dense ``source.sample`` — bit for bit — so the
   cohort path changes which clients train, never what they see.
3. Every stateful rule (fedau / mifa / f3ast / fedpbc_m) has a sparse
   cohort branch whose scatters touch cohort rows only.
4. The buffered strategy axis is one more traced batch dimension: a
   (SYNC, buffered) sweep compiles ONE (init, scan) program, and its
   store rows carry the strategy coordinate.
5. ``SweepSpec`` rejects malformed ``strategies`` / ``cohort_size`` axes
   at construction with the offending field named.
6. The buffer engine's commit policy matches its spec: ``wait_for_full``
   holds until the buffer fills; otherwise the deadline forces a commit.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederationConfig
from repro.core import init_fed_state, make_link_process, make_run_rounds
from repro.core.algorithms import make_algorithm_spec
from repro.data import classification_source, fixed_source
from repro.experiments import ResultsStore, SweepSpec, run_sweep
from repro.experiments.grid import _runner_for, get_traced_task
from repro.optim import sgd
from repro.scale import (
    BUFFER_METRIC_KEYS,
    SYNC,
    Strategy,
    buffered_aggregate,
    init_buffer_state,
    knobs_of,
    sample_cohort,
    strategy_knob_columns,
)
from repro.kernels.masked_agg import OP_MEAN

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

BASE = SweepSpec(algorithms=("fedpbc",), seeds=(0, 1), num_clients=8, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=4, local_steps=2, rounds=4, eval_every=2,
                 lrs=(0.1,))
BUFFERED = Strategy("buffered", buffer_size=4, deadline_rounds=3)
METRIC_KEYS = ("loss", "num_active") + BUFFER_METRIC_KEYS


def _quadratic_setup(m, C=None, *, algo="fedpbc", p=0.5, strategy=None,
                     scheme="bernoulli"):
    """A tiny quadratic federated problem on the real engine."""
    fed = FederationConfig(algorithm=algo, num_clients=m, local_steps=2,
                           scheme=scheme)
    spec = make_algorithm_spec((algo,), fed)
    link = make_link_process(jnp.full((m,), p), fed)
    loss = lambda params, batch: jnp.sum((params["x"] - batch["u"].sum()) ** 2)
    opt = sgd(0.05)
    source = fixed_source({"u": jnp.zeros((m, fed.local_steps, 1))})
    run = make_run_rounds(loss, opt, spec, link, fed, source,
                          metric_keys=("loss", "num_active", "staleness")
                          + (BUFFER_METRIC_KEYS if strategy is not None
                             or C is not None else ()),
                          donate=False, strategy=strategy, cohort_size=C)
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.ones(3)}, fed, spec,
                        link, opt, stateless_clients=C is not None,
                        buffered=strategy is not None
                        or (C is not None and spec.fusable))
    return run, st, source.init(jax.random.PRNGKey(2))


# ---------------------------------------------------------------------------
# 1. O(C) memory
# ---------------------------------------------------------------------------

def test_cohort_round_memory_is_o_of_c():
    """At m=50_000 the cohort engine must hold NO [m, n_params] tensor:
    client params/opt state are () and every FedState leaf is either O(m)
    scalars-per-client bookkeeping or O(n_params) server/buffer state."""
    m, C, n_params = 50_000, 256, 3
    run, st, ds = _quadratic_setup(m, C)
    assert st.clients == () and st.opt_state == ()
    for leaf in jax.tree.leaves(st):
        assert leaf.size <= max(m, 64 * n_params)   # never m x n_params
    st, ds, mets = run(st, ds, jax.random.PRNGKey(3), 2)
    assert st.clients == () and st.opt_state == ()
    assert np.isfinite(np.asarray(mets["loss"])).all()
    # the round saw C-sized cohorts, not the population
    assert float(np.asarray(mets["num_active"]).max()) <= C


def test_cohort_sampler_validates_and_is_unique():
    key = jax.random.PRNGKey(0)
    cohort = np.asarray(sample_cohort(key, 100, 32))
    assert cohort.shape == (32,) and len(set(cohort.tolist())) == 32
    assert cohort.min() >= 0 and cohort.max() < 100
    with pytest.raises(ValueError, match="cohort"):
        sample_cohort(key, 100, 0)
    with pytest.raises(ValueError, match="cohort"):
        sample_cohort(key, 100, 101)


# ---------------------------------------------------------------------------
# 2. cohort data == dense data on the full population
# ---------------------------------------------------------------------------

def test_sample_cohort_full_population_is_dense_sample():
    m, s, b, d = 6, 2, 3, 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(40, d)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 3, size=(40,)), jnp.int32)
    idx = jnp.asarray(rng.integers(0, 40, size=(m, 8)), jnp.int32)
    src = classification_source(x, y, idx, local_steps=s, batch_size=b)
    ds = src.init(jax.random.PRNGKey(1))
    key = jax.random.PRNGKey(7)
    dense, _ = src.sample(ds, 3, key)
    cohort, _ = src.sample_cohort(ds, 3, key, jnp.arange(m))
    for a, c in zip(jax.tree.leaves(dense), jax.tree.leaves(cohort)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# 3. stateful rules: sparse cohort branches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["fedau", "mifa", "f3ast", "fedpbc_m"])
def test_stateful_cohort_engine_runs_and_touches_cohort_rows_only(algo):
    m, C = 64, 8
    run, st, ds = _quadratic_setup(m, C, algo=algo)
    st1, ds, mets = run(st, ds, jax.random.PRNGKey(3), 5)
    assert np.isfinite(np.asarray(mets["loss"])).all()
    assert np.isfinite(
        np.asarray(jax.tree.leaves(st1.server)[0], np.float64)).all()
    # rows never sampled into a cohort keep their initial state: with
    # 5 rounds x C=8 at most 40 of 64 rows were touched
    touched = np.asarray(st1.last_active) >= 0
    assert touched.sum() <= 5 * C
    if algo == "mifa":
        mem0 = np.asarray(jax.tree.leaves(st.algo_state.mem)[0])
        mem1 = np.asarray(jax.tree.leaves(st1.algo_state.mem)[0])
        unchanged = np.all((mem0 == mem1).reshape(m, -1), axis=-1)
        assert unchanged.sum() >= m - 5 * C


def test_buffered_strategy_refused_for_stateful_rules():
    m = 8
    fed = FederationConfig(algorithm="fedau", num_clients=m, local_steps=2)
    spec = make_algorithm_spec(("fedau",), fed)
    link = make_link_process(jnp.full((m,), 0.5), fed)
    with pytest.raises(ValueError, match="empty-state family"):
        make_run_rounds(lambda p, b: jnp.sum(p["x"] ** 2), sgd(0.1), spec,
                        link, fed, fixed_source({"u": jnp.zeros((m, 2, 1))}),
                        strategy=BUFFERED)


# ---------------------------------------------------------------------------
# 4. the strategy axis is one compiled program
# ---------------------------------------------------------------------------

def test_buffered_sweep_compiles_one_program_and_records_strategy(
        tmp_path, compiles_once):
    spec = dataclasses.replace(BASE, strategies=(SYNC, BUFFERED),
                               schemes=("bernoulli_ti",))
    store = ResultsStore(str(tmp_path / "sweeps"))
    cells = run_sweep(spec, store=store, suite="scale",
                      metric_keys=METRIC_KEYS)
    assert [c.strategy for c in cells] == ["sync", "buffered"]
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    runner = _runner_for(spec, fed, get_traced_task(spec), METRIC_KEYS)
    # both strategies (and any knob grid) share ONE (init, scan) pair —
    # the knobs are traced per-trajectory columns, not compile constants
    compiles_once(runner.init_batch, runner.scan_batch)
    rows = store.records(suite="scale")
    assert [r["strategy"] for r in rows] == ["sync", "buffered"]
    # buffered rows carry the commit trace; its cadence is a real policy
    # (neither no-commit nor the sync every-round commit)
    sync_c, buf_c = cells
    assert buf_c.commit is not None
    commits = np.asarray(buf_c.commit).sum(axis=1)
    assert (commits >= 1).all() and (commits < spec.rounds).all()
    assert (np.asarray(sync_c.commit).sum(axis=1) == spec.rounds).all()
    summ = buf_c.summary()
    assert "commits" in summ and "commit_staleness" in summ
    assert "participation" in summ


@multi_device
def test_buffered_sweep_sharded_matches_single_device():
    spec = dataclasses.replace(BASE, strategies=(SYNC, BUFFERED),
                               schemes=("bernoulli_ti",))
    ref = run_sweep(spec, metric_keys=METRIC_KEYS, devices=jax.devices()[:1])
    sh = run_sweep(spec, metric_keys=METRIC_KEYS)
    assert [c.strategy for c in sh] == [c.strategy for c in ref]
    for a, b in zip(sh, ref):
        np.testing.assert_array_equal(a.test_acc, b.test_acc)
        np.testing.assert_array_equal(a.loss, b.loss)
        np.testing.assert_array_equal(np.asarray(a.commit),
                                      np.asarray(b.commit))


def test_cohort_sweep_runs_at_scale_smoke():
    """The acceptance workload shape (large m, C=256 cohort, buffered
    strategy) as a fast smoke: one compiled program, finite results."""
    spec = dataclasses.replace(
        BASE, num_clients=10_000, cohort_size=64,
        strategies=(Strategy("buf", buffer_size=48, deadline_rounds=2),),
        schemes=("bernoulli_ti",), seeds=(0,), rounds=3, eval_every=3)
    cells = run_sweep(spec, metric_keys=METRIC_KEYS)
    (cell,) = cells
    assert cell.strategy == "buf"
    assert np.isfinite(cell.test_acc).all()
    assert float(np.asarray(cell.num_active).max()) <= 64
    # participation is measured against the cohort, not the population
    assert 0.0 <= cell.summary()["participation"]["mean"] <= 1.0


# ---------------------------------------------------------------------------
# 5. SweepSpec validation
# ---------------------------------------------------------------------------

def test_sweep_spec_strategy_axis_validation_names_offending_field():
    with pytest.raises(ValueError, match="SweepSpec.strategies is empty"):
        dataclasses.replace(BASE, strategies=())
    with pytest.raises(ValueError, match="SweepSpec.strategies entries"):
        dataclasses.replace(BASE, strategies=(SYNC, "buffered"))
    with pytest.raises(ValueError,
                       match="SweepSpec.strategies.*duplicate.*sync"):
        dataclasses.replace(BASE, strategies=(SYNC, Strategy("sync")))
    with pytest.raises(ValueError,
                       match=r"SweepSpec.strategies\['big'\].buffer_size"):
        dataclasses.replace(BASE, strategies=(
            Strategy("big", buffer_size=BASE.num_clients + 1),))
    with pytest.raises(ValueError,
                       match=r"SweepSpec.strategies\['big'\].buffer_size"):
        # with a cohort, the buffer can only ever see C arrivals per round
        dataclasses.replace(BASE, cohort_size=4,
                            strategies=(Strategy("big", buffer_size=6),))
    with pytest.raises(ValueError,
                       match=r"SweepSpec.strategies\['rush'\].deadline"):
        dataclasses.replace(BASE, strategies=(
            Strategy("rush", deadline_rounds=0),))
    with pytest.raises(ValueError,
                       match=r"SweepSpec.strategies\['hot'\].staleness"):
        dataclasses.replace(BASE, strategies=(
            Strategy("hot", staleness_discount=1.5),))
    with pytest.raises(ValueError, match="SweepSpec.cohort_size"):
        dataclasses.replace(BASE, cohort_size=0)
    with pytest.raises(ValueError, match="SweepSpec.cohort_size"):
        dataclasses.replace(BASE, cohort_size=BASE.num_clients + 1)
    with pytest.raises(ValueError, match="buffered entries"):
        dataclasses.replace(BASE, algorithms=("fedau",),
                            strategies=(SYNC, BUFFERED))
    # valid axes still construct
    dataclasses.replace(BASE, strategies=(SYNC, BUFFERED), cohort_size=4)


def test_knob_normalization_and_columns():
    assert knobs_of(None) == knobs_of(SYNC)
    assert SYNC.is_sync and not BUFFERED.is_sync
    assert Strategy("w", wait_for_full=True, buffer_size=1).is_sync is False
    with pytest.raises(ValueError, match="missing"):
        knobs_of({"buffer_size": 4})
    cols = strategy_knob_columns((SYNC, BUFFERED), block=3)
    assert set(cols) == {"wait_for_full", "buffer_size", "deadline_rounds",
                        "staleness_discount"}
    np.testing.assert_array_equal(np.asarray(cols["buffer_size"]),
                                  [1, 1, 1, 4, 4, 4])
    assert cols["wait_for_full"].dtype == jnp.bool_
    assert cols["staleness_discount"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# 6. buffer engine commit policy
# ---------------------------------------------------------------------------

def _fold(buf, server, active, knobs):
    m = active.shape[0]
    x_star = {"x": jnp.ones((m, 2))}
    in_buffer = buf.in_buffer | active
    return buffered_aggregate(buf, server, x_star, active,
                              jnp.full((m,), 0.5), knobs, op=OP_MEAN,
                              m_total=m, in_buffer_new=in_buffer)


def test_wait_for_full_commits_only_when_full():
    m = 4
    server = {"x": jnp.zeros(2)}
    knobs = knobs_of(Strategy("w", wait_for_full=True, buffer_size=3,
                              deadline_rounds=1))
    buf = init_buffer_state(server, m)
    two = jnp.asarray([True, True, False, False])
    buf, srv, commit, mets = _fold(buf, server, two, knobs)
    assert not bool(commit)                      # 2 < 3: deadline ignored
    assert float(mets["buffer_fill"]) == 2.0
    np.testing.assert_array_equal(np.asarray(jax.tree.leaves(srv)[0]), 0.0)
    buf, srv, commit, mets = _fold(buf, server, two, knobs)
    assert bool(commit)                          # 4 >= 3: fills, commits
    assert float(buf.count) == 0 and not bool(buf.in_buffer.any())
    # committed mean of four all-ones contributions is exactly ones
    np.testing.assert_array_equal(np.asarray(srv["x"]), 1.0)
    # the first two contributions waited one round, the new two zero
    assert float(mets["commit_staleness"]) == pytest.approx(0.5)


def test_deadline_forces_commit_on_empty_rounds():
    m = 4
    server = {"x": jnp.zeros(2)}
    # buffer_size 4 never fills with one arrival per round; the deadline acts
    knobs = knobs_of(Strategy("d", buffer_size=4, deadline_rounds=2))
    buf = init_buffer_state(server, m)
    one = jnp.asarray([True, False, False, False])
    buf, _, commit, _ = _fold(buf, server, one, knobs)
    assert not bool(commit)                      # 1 < 4 and 1 < deadline 2
    buf, srv, commit, _ = _fold(buf, server, one, knobs)
    assert bool(commit)                          # deadline reached
    np.testing.assert_array_equal(np.asarray(srv["x"]), 1.0)
    assert float(buf.commits) == 1.0


def test_staleness_discount_downweights_without_bias():
    m = 2
    server = {"x": jnp.zeros(1)}
    knobs = knobs_of(Strategy("s", buffer_size=2, deadline_rounds=10,
                              staleness_discount=0.5))
    buf = init_buffer_state(server, m)
    first = jnp.asarray([True, False])
    second = jnp.asarray([False, True])
    x_old = {"x": jnp.full((m, 1), 4.0)}
    x_new = {"x": jnp.full((m, 1), 1.0)}
    buf, _, commit, _ = buffered_aggregate(
        buf, server, x_old, first, jnp.full((m,), 0.5), knobs, op=OP_MEAN,
        m_total=m, in_buffer_new=buf.in_buffer | first)
    assert not bool(commit)
    buf, srv, commit, _ = buffered_aggregate(
        buf, server, x_new, second, jnp.full((m,), 0.5), knobs, op=OP_MEAN,
        m_total=m, in_buffer_new=buf.in_buffer | second)
    assert bool(commit)
    # discounted mean: (0.5*4 + 1) / (0.5 + 1) = 2, between the stale (4)
    # and fresh (1) values but closer to fresh — down-weighted, not biased
    assert float(srv["x"][0]) == pytest.approx(2.0)
