"""Multi-round scan engine: scanned ``run_rounds`` must be bit-for-bit
identical to sequential ``round_fn`` dispatches over the same ``DataSource``,
and checkpointing mid-scan-chunk must resume the exact trajectory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import restore, save
from repro.configs import FederationConfig
from repro.core import (
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_round_fn,
    make_run_rounds,
    run_rounds_loop,
)
from repro.data import (
    classification_source,
    dirichlet_partition,
    fixed_source,
    lm_source,
    make_classification_data,
)
from repro.optim import paper_decay, sgd

M, S, B = 8, 3, 4


def _mlp_init(key, dim=16, classes=10, hidden=8):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * hidden ** -0.5,
        "b2": jnp.zeros(classes),
    }


def _mlp_loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def _source(seed=0):
    rng = np.random.default_rng(seed)
    x, y = make_classification_data(seed, dim=16, n_per_class=60, sep=3.0)
    idx, _ = dirichlet_partition(rng, y, M, alpha=0.5, per_client=24)
    return classification_source(x, y, idx, local_steps=S, batch_size=B)


def _problem(algo_name, scheme, seed=0):
    fed = FederationConfig(algorithm=algo_name, num_clients=M, local_steps=S,
                           scheme=scheme)
    # uniform-ish p so aggregation actually fires most rounds
    p = jnp.linspace(0.3, 0.9, M)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    opt = sgd(paper_decay(0.1))
    params = _mlp_init(jax.random.PRNGKey(seed + 1))
    st = init_fed_state(jax.random.PRNGKey(seed + 2), params, fed, algo,
                        link, opt)
    return fed, algo, link, opt, st


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("algo_name", ["fedpbc", "fedavg"])
@pytest.mark.parametrize("scheme", ["bernoulli", "markov"])
def test_scan_matches_sequential_bit_for_bit(algo_name, scheme):
    source = _source()
    fed, algo, link, opt, st0 = _problem(algo_name, scheme)
    ds0 = source.init(jax.random.PRNGKey(4))
    data_key = jax.random.PRNGKey(5)
    K = 6

    # donate=False: st0/ds0 are deliberately reused by both paths below
    run_rounds = make_run_rounds(_mlp_loss, opt, algo, link, fed, source,
                                 donate=False)
    st_scan, ds_scan, met_scan = run_rounds(st0, ds0, data_key, K)

    round_fn = make_round_fn(_mlp_loss, opt, algo, link, fed)
    st_seq, ds_seq, met_seq = run_rounds_loop(
        st0, ds0, data_key, K, round_fn=round_fn, source=source)

    _assert_trees_equal(st_scan, st_seq)
    _assert_trees_equal(ds_scan, ds_seq)
    assert met_scan["loss"].shape == (K,)
    assert met_scan["staleness"].shape == (K, M)
    for k in met_scan:
        np.testing.assert_array_equal(np.asarray(met_scan[k]),
                                      np.asarray(met_seq[k]))


def test_run_rounds_loop_zero_rounds_metric_shapes():
    """num_rounds=0 must return metrics with the true per-round trailing
    shapes ([0, m] for staleness, not a bare [0]) and leave state untouched."""
    source = _source()
    fed, algo, link, opt, st0 = _problem("fedpbc", "bernoulli")
    ds0 = source.init(jax.random.PRNGKey(4))
    round_fn = make_round_fn(_mlp_loss, opt, algo, link, fed)
    st, ds, mets = run_rounds_loop(st0, ds0, jax.random.PRNGKey(5), 0,
                                   round_fn=round_fn, source=source)
    assert mets["loss"].shape == (0,)
    assert mets["num_active"].shape == (0,)
    assert mets["staleness"].shape == (0, M)
    assert mets["staleness"].dtype == jnp.float32
    _assert_trees_equal(st, st0)
    _assert_trees_equal(ds, ds0)


def test_chunked_scan_matches_single_scan():
    """K rounds as one scan == the same K rounds split across chunks."""
    source = _source()
    fed, algo, link, opt, st0 = _problem("fedpbc", "bernoulli")
    ds0 = source.init(jax.random.PRNGKey(4))
    data_key = jax.random.PRNGKey(5)
    run_rounds = make_run_rounds(_mlp_loss, opt, algo, link, fed, source,
                                 donate=False)

    st_a, ds_a, _ = run_rounds(st0, ds0, data_key, 8)
    st_b, ds_b = st0, ds0
    for chunk in (3, 4, 1):
        st_b, ds_b, _ = run_rounds(st_b, ds_b, data_key, chunk)
    _assert_trees_equal(st_a, st_b)
    _assert_trees_equal(ds_a, ds_b)


def test_checkpoint_roundtrip_mid_chunk(tmp_path):
    """save/restore of (FedState, ds_state) between scan chunks resumes the
    exact trajectory (lm_source carries nontrivial ds_state)."""
    source = lm_source(num_clients=M, local_steps=S, batch=2, seq=8, vocab=64)

    def loss(params, batch):
        # embedding-free toy LM loss over the synthetic token stream
        logits = batch["tokens"][..., None] * params["w"]
        labels = jax.nn.one_hot(batch["labels"] % 4, 4)
        return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))

    fed = FederationConfig(algorithm="fedpbc", num_clients=M, local_steps=S)
    algo = make_algorithm(fed)
    link = make_link_process(jnp.full((M,), 0.6), fed)
    opt = sgd(0.05)
    st0 = init_fed_state(jax.random.PRNGKey(1), {"w": 0.01 * jnp.ones(4)},
                         fed, algo, link, opt)
    ds0 = source.init(jax.random.PRNGKey(2))
    data_key = jax.random.PRNGKey(3)
    run_rounds = make_run_rounds(loss, opt, algo, link, fed, source,
                                 donate=False)

    # uninterrupted 4 + 4
    st_a, ds_a, _ = run_rounds(st0, ds0, data_key, 8)

    # run 4, checkpoint, restore into a fresh template, run 4 more
    st_b, ds_b, _ = run_rounds(st0, ds0, data_key, 4)
    ckpt = tmp_path / "ckpt"
    save(str(ckpt), 4, (st_b, ds_b))
    st_r, ds_r = restore(str(ckpt), 4, (st0, ds0))
    assert int(st_r.round) == 4
    st_c, ds_c, _ = run_rounds(st_r, ds_r, data_key, 4)

    _assert_trees_equal(st_a, st_c)
    _assert_trees_equal(ds_a, ds_c)


def test_fixed_source_run_rounds_converges():
    """End-to-end sanity on the quadratic: scanned engine reaches the optimum."""
    m, d, s = 10, 4, 5
    key = jax.random.PRNGKey(0)
    u = (jnp.arange(m) / m)[:, None] + 0.05 * jax.random.normal(key, (m, d))
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(jnp.full((m,), 0.5), fed)
    loss = lambda params, batch: 0.5 * jnp.sum((params["x"] - batch["u"]) ** 2)
    opt = sgd(0.005)
    source = fixed_source({"u": jnp.broadcast_to(u[:, None], (m, s, d))})
    run_rounds = make_run_rounds(loss, opt, algo, link, fed, source)
    st = init_fed_state(jax.random.PRNGKey(1), {"x": jnp.zeros(d)}, fed,
                       algo, link, opt)
    st, _, mets = run_rounds(st, source.init(jax.random.PRNGKey(2)),
                             jax.random.PRNGKey(3), 300)
    assert mets["loss"].shape == (300,)
    err = float(jnp.linalg.norm(st.server["x"] - u.mean(0)))
    assert err < 0.12, err
