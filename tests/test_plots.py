"""Contract tests for ``repro.experiments.plots`` curve exports.

Pins the three behaviors downstream tooling depends on: the CSV column
contract (``round,mean,std,ci95,n_seeds`` with values matching a direct
numpy computation), the single-seed degenerate case (std/ci95 exactly 0, not
NaN from a ddof=1 std of one row), and the empty-store case (a clear
``ValueError`` naming the store instead of a silent zero-file export).
"""
import math

import numpy as np
import pytest

from repro.experiments.plots import export_curves
from repro.experiments.results import ResultsStore


def _append(store, *, algo="fedpbc", seeds=(0,), test_acc, loss=None,
            eval_rounds=None, suite="t1"):
    arrays = {"test_acc": np.asarray(test_acc, np.float64)}
    if loss is not None:
        arrays["loss"] = np.asarray(loss, np.float64)
    rec = {"suite": suite, "algo": algo, "scheme": "bernoulli_ti",
           "seeds": list(seeds), "rounds": 5, "eval_every": 2,
           "hparams": {"lr": 0.1}, "spec": {"num_clients": 8}}
    if eval_rounds is not None:
        rec["eval_rounds"] = eval_rounds
    return store.append(rec, arrays=arrays)


def _read_csv(path):
    with open(path) as f:
        header = f.readline().strip()
        rows = [line.strip().split(",") for line in f if line.strip()]
    return header, rows


def test_curve_csv_column_contract(tmp_path):
    """Header and per-column values are pinned: round indices come from the
    record's eval_rounds (acc) / 1..K (loss), and mean/std/ci95 match the
    textbook seed-axis formulas."""
    store = ResultsStore(str(tmp_path / "s"))
    acc = [[0.2, 0.5, 0.8], [0.4, 0.7, 0.6]]
    loss = [[1.0, 0.8], [0.6, 0.4]]
    _append(store, seeds=[0, 1], test_acc=acc, loss=loss,
            eval_rounds=[2, 4, 5])
    written = export_curves(store, str(tmp_path / "curves"))
    acc_path = [p for p in written if p.endswith("_acc.csv")][0]
    loss_path = [p for p in written if p.endswith("_loss.csv")][0]

    header, rows = _read_csv(acc_path)
    assert header == "round,mean,std,ci95,n_seeds"
    assert [int(r[0]) for r in rows] == [2, 4, 5]
    a = np.asarray(acc)
    for i, r in enumerate(rows):
        assert float(r[1]) == pytest.approx(a[:, i].mean(), abs=1e-6)
        std = a[:, i].std(ddof=1)
        assert float(r[2]) == pytest.approx(std, abs=1e-6)
        assert float(r[3]) == pytest.approx(1.96 * std / math.sqrt(2),
                                            abs=1e-6)
        assert int(r[4]) == 2

    header, rows = _read_csv(loss_path)
    assert header == "round,mean,std,ci95,n_seeds"
    assert [int(r[0]) for r in rows] == [1, 2]     # per-round axis is 1-based


def test_single_seed_store_exports_zero_width_ci(tmp_path):
    """One seed: std and ci95 are exactly 0.0 (no ddof=1 NaN), mean is the
    seed's own curve."""
    store = ResultsStore(str(tmp_path / "s"))
    _append(store, seeds=[7], test_acc=[[0.25, 0.75]], eval_rounds=[2, 4])
    written = export_curves(store, str(tmp_path / "curves"))
    assert len(written) == 1
    header, rows = _read_csv(written[0])
    assert header == "round,mean,std,ci95,n_seeds"
    assert [float(r[1]) for r in rows] == [0.25, 0.75]
    assert all(float(r[2]) == 0.0 and float(r[3]) == 0.0 for r in rows)
    assert all(int(r[4]) == 1 for r in rows)


def test_variable_length_trajectories_pool_with_per_round_counts(tmp_path):
    """Two same-curve records whose rows have DIFFERENT lengths (an
    early-pruned search trajectory pooled with a longer one) NaN-pad to the
    longest row and summarize per round over the seeds that reached it —
    the old uniform-[E] ``np.stack`` would have crashed outright."""
    store = ResultsStore(str(tmp_path / "s"))
    _append(store, seeds=[0], test_acc=[[0.2, 0.4]], eval_rounds=[2, 4])
    _append(store, seeds=[1], test_acc=[[0.3]], eval_rounds=[2])
    written = export_curves(store, str(tmp_path / "curves"))
    assert len(written) == 1
    header, rows = _read_csv(written[0])
    assert header == "round,mean,std,ci95,n_seeds"
    # round 2: both seeds; round 4 (from the LONGER record's eval axis):
    # only seed 0 — n_seeds drops to 1 and std/ci95 are exactly 0
    assert [int(r[0]) for r in rows] == [2, 4]
    assert float(rows[0][1]) == pytest.approx(0.25, abs=1e-6)
    assert int(rows[0][4]) == 2
    assert float(rows[1][1]) == pytest.approx(0.4, abs=1e-6)
    assert float(rows[1][2]) == 0.0 and float(rows[1][3]) == 0.0
    assert int(rows[1][4]) == 1


def test_empty_store_raises_clear_error(tmp_path):
    """An empty/missing store (or an over-narrow filter) is a caller mistake:
    export_curves must say so, naming the store, instead of writing nothing."""
    empty = ResultsStore(str(tmp_path / "nothing-here"))
    with pytest.raises(ValueError, match="no records to export.*nothing-here"):
        export_curves(empty, str(tmp_path / "curves"))

    store = ResultsStore(str(tmp_path / "s"))
    _append(store, test_acc=[[0.5]], suite="present")
    with pytest.raises(ValueError, match="matching filters.*absent"):
        export_curves(store, str(tmp_path / "curves"), suite="absent")
    # the matching suite still exports
    assert export_curves(store, str(tmp_path / "curves"), suite="present")
