"""Aggregation-rule unit + integration tests over pytrees."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare image: fall back to seeded-random example cases
    HAVE_HYPOTHESIS = False

from repro.configs import FederationConfig
from repro.core import (
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_round_fn,
    masked_mean,
)
from repro.core.algorithms import ALGORITHMS, bcast_where
from repro.optim import sgd

ALGOS = list(ALGORITHMS)


def _check_masked_mean(m, bits):
    mask = jnp.asarray([(bits >> i) & 1 for i in range(m)], jnp.float32)
    x = {"a": jnp.arange(m * 3, dtype=jnp.float32).reshape(m, 3),
         "b": jnp.ones((m, 2, 2))}
    out = masked_mean(x, mask)
    sel = np.where(np.asarray(mask) > 0)[0]
    if len(sel):
        np.testing.assert_allclose(
            out["a"], np.asarray(x["a"])[sel].mean(0), rtol=1e-6)
        np.testing.assert_allclose(out["b"], 1.0)
    else:
        np.testing.assert_allclose(out["a"], 0.0)


if HAVE_HYPOTHESIS:

    @given(st.integers(2, 10), st.integers(0, 2 ** 10 - 1))
    @settings(max_examples=60, deadline=None)
    def test_masked_mean_property(m, bits):
        _check_masked_mean(m, bits)

else:
    _rng = np.random.default_rng(0)
    _CASES = (
        # edge cases hypothesis would shrink to: empty mask, full mask
        [(2, 0), (10, 0), (2, 3), (10, 2 ** 10 - 1)]
        + [(int(_rng.integers(2, 11)), int(_rng.integers(0, 2 ** 10)))
           for _ in range(56)]
    )

    @pytest.mark.parametrize("m,bits", _CASES)
    def test_masked_mean_property(m, bits):
        _check_masked_mean(m, bits)


def test_bcast_where():
    m = 4
    old = {"w": jnp.arange(m * 2, dtype=jnp.float32).reshape(m, 2)}
    new = {"w": jnp.full((2,), -1.0)}
    act = jnp.asarray([True, False, True, False])
    out = bcast_where(act, new, old)
    np.testing.assert_allclose(out["w"][0], -1.0)
    np.testing.assert_allclose(out["w"][1], old["w"][1])


def _run_quadratic(algo_name, p, T=400, eta=0.002, s=10, m=10, d=6, seed=0):
    key = jax.random.PRNGKey(seed)
    u = (jnp.arange(m) / m)[:, None] + 0.05 * jax.random.normal(key, (m, d))
    fed = FederationConfig(algorithm=algo_name, num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    loss = lambda params, batch: 0.5 * jnp.sum((params["x"] - batch["u"]) ** 2)
    opt = sgd(eta)
    rf = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    st_ = init_fed_state(jax.random.PRNGKey(1), {"x": jnp.zeros(d)}, fed, algo, link, opt)
    batches = {"u": jnp.broadcast_to(u[:, None], (m, s, d))}
    for _ in range(T):
        st_, mets = rf(st_, batches)
    x_star = u.mean(0)
    return float(jnp.linalg.norm(st_.server["x"] - x_star))


@pytest.mark.parametrize("name", ALGOS)
def test_all_algorithms_converge_uniform_p(name):
    """Uniform availability: every algorithm should reach the optimum."""
    err = _run_quadratic(name, jnp.full((10,), 0.5))
    assert err < 0.12, (name, err)


@pytest.mark.slow
def test_fedpbc_beats_fedavg_under_heterogeneous_p():
    """The paper's core claim at engine level."""
    m = 10
    p = jnp.where(jnp.arange(m) < m // 2, 0.9, 0.1)
    err_pbc = _run_quadratic("fedpbc", p, T=1500, eta=0.001)
    err_avg = _run_quadratic("fedavg", p, T=1500, eta=0.001)
    assert err_pbc < 0.5 * err_avg, (err_pbc, err_avg)


def test_fedpbc_postponed_broadcast_semantics():
    """Inactive clients keep their own local model; active ones get the mean."""
    from repro.core.algorithms import fedpbc
    algo = fedpbc()
    m = 4
    server = {"w": jnp.zeros(2)}
    clients = {"w": jnp.stack([jnp.full(2, float(i)) for i in range(m)])}
    x_star = {"w": clients["w"] + 10.0}
    active = jnp.asarray([True, False, True, False])
    _, new_server, new_clients = algo.aggregate(
        (), server, clients, x_star, active, None, 0)
    np.testing.assert_allclose(new_server["w"], (10.0 + 12.0) / 2)
    np.testing.assert_allclose(new_clients["w"][0], new_server["w"])  # active
    np.testing.assert_allclose(new_clients["w"][2], new_server["w"])
    np.testing.assert_allclose(new_clients["w"][1], x_star["w"][1])   # stale
    np.testing.assert_allclose(new_clients["w"][3], x_star["w"][3])


def test_fedpbc_empty_round_keeps_server():
    from repro.core.algorithms import fedpbc
    algo = fedpbc()
    server = {"w": jnp.ones(3)}
    clients = {"w": jnp.zeros((4, 3))}
    _, new_server, _ = algo.aggregate(
        (), server, clients, clients, jnp.zeros(4, bool), None, 0)
    np.testing.assert_allclose(new_server["w"], server["w"])


def test_mifa_uses_stale_memory():
    from repro.core.algorithms import mifa
    algo = mifa()
    m = 2
    server = {"w": jnp.zeros(1)}
    state = algo.init(server, m)
    clients = {"w": jnp.zeros((m, 1))}
    # round 0: only client 0 active with update +2
    x_star = {"w": jnp.asarray([[2.0], [0.0]])}
    state, server, clients = algo.aggregate(
        state, server, clients, x_star, jnp.asarray([True, False]), None, 0)
    np.testing.assert_allclose(server["w"], [1.0])  # (2 + 0)/2
    # round 1: nobody active -> server still moves by the remembered update
    x_star = {"w": jnp.broadcast_to(server["w"], (m, 1))}
    state, server2, _ = algo.aggregate(
        state, server, clients, x_star, jnp.zeros(m, bool), None, 1)
    np.testing.assert_allclose(server2["w"], server["w"] + 1.0)
