"""The fused aggregation kernel on the sweep hot path.

Acceptance guarantees of the kernel-dispatch layer:

1. ``AlgorithmSpec.aggregate(..., use_kernel=True)`` equals the XLA switch
   path bitwise (fp32, CPU) for every fusable family member — static and
   traced ``algo_id``, including zero-active rounds.
2. A full batched family sweep with ``use_kernel=True`` is bit-for-bit
   equal per trajectory to the XLA-path sweep, on the single-device path
   and on a multi-device ``("batch",)`` mesh (CI runs this file under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
3. Enabling ``use_kernel`` adds ZERO extra jit cache entries: a whole
   4-algorithm family ``run_sweep`` still compiles exactly one (init, scan)
   pair — the fused program rides the same runner cache.
4. Non-fusable families (stateful rules) fall back to the switch path
   unchanged under ``use_kernel=True``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.algorithms import AlgorithmSpec, algo_family
from repro.experiments import SweepSpec, run_sweep
from repro.experiments.grid import (
    _runner_for,
    get_traced_task,
    make_cell_batch,
)
from repro.experiments.shard import resolve_batch_mesh, run_sharded

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SEEDS = (0, 1)
BASE = SweepSpec(seeds=SEEDS, num_clients=8, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=4, local_steps=3, rounds=5, eval_every=2,
                 lrs=(0.05, 0.1))
KSPEC = dataclasses.replace(BASE, use_kernel=True)
METRIC_KEYS = ("loss", "num_active")
FAMILY = algo_family("fedavg")
SCHEME = "bernoulli_tv"    # time-varying p_t exercises the known-p weighting


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _agg_inputs(key, m=6, empty=False):
    x_star = {"w": jax.random.normal(key, (m, 5, 3)),
              "b": jax.random.normal(jax.random.fold_in(key, 1), (m, 3))}
    server = {"w": jax.random.normal(jax.random.fold_in(key, 2), (5, 3)),
              "b": jax.random.normal(jax.random.fold_in(key, 3), (3,))}
    clients = jax.tree.map(
        lambda s: jnp.broadcast_to(s, (m,) + s.shape), server)
    active = (jnp.zeros((m,), bool) if empty
              else jax.random.uniform(jax.random.fold_in(key, 4), (m,)) < 0.5)
    p_t = jax.random.uniform(jax.random.fold_in(key, 5), (m,),
                             minval=0.05, maxval=1.0)
    return x_star, server, clients, active, p_t


@pytest.mark.parametrize("empty", [False, True])
def test_fused_aggregate_matches_switch_static(empty):
    """Per-member static dispatch: the fused kernel's (algo_state, server,
    clients) triple equals the XLA branch exactly — including the
    zero-active round, where both must preserve the server params."""
    spec = AlgorithmSpec(FAMILY)
    key = jax.random.PRNGKey(3 + empty)
    x_star, server, clients, active, p_t = _agg_inputs(key, empty=empty)
    state = spec.init(server, active.shape[0])
    for aid in range(len(FAMILY)):
        want = spec.aggregate(aid, state, server, clients, x_star, active,
                              p_t, jnp.int32(0))
        got = spec.aggregate(aid, state, server, clients, x_star, active,
                             p_t, jnp.int32(0), use_kernel=True)
        _assert_trees_equal(got, want)


def test_fused_aggregate_matches_switch_traced_batched():
    """Traced per-trajectory ``algo_id`` under vmap — the sweep layout: the
    one-pass fused kernel equals the evaluate-every-branch switch bitwise
    for a batch mixing all four members (and a zero-active trajectory)."""
    spec = AlgorithmSpec(FAMILY)
    B = 5
    keys = jax.random.split(jax.random.PRNGKey(11), B)
    ins = [_agg_inputs(k, empty=(i == 2)) for i, k in enumerate(keys)]
    x_star, server, clients, active, p_t = jax.tree.map(
        lambda *xs: jnp.stack(xs), *ins)
    algo_id = jnp.asarray([0, 1, 2, 3, 1], jnp.int32)
    m = active.shape[1]
    state = spec.init(jax.tree.map(lambda s: s[0], server), m)
    states = jax.tree.map(lambda s: jnp.broadcast_to(s, (B,) + s.shape), state)

    def run(uk):
        return jax.jit(jax.vmap(
            lambda aid, st, sv, cl, xs, act, pt: spec.aggregate(
                aid, st, sv, cl, xs, act, pt, jnp.int32(0), use_kernel=uk)))(
            algo_id, states, server, clients, x_star, active, p_t)

    _assert_trees_equal(run(True), run(False))


def test_non_fusable_family_falls_back_to_switch():
    """use_kernel=True on a stateful (non-fusable) family is a no-op: the
    switch path runs and results are identical."""
    spec = AlgorithmSpec(("fedau",))
    assert not spec.fusable
    key = jax.random.PRNGKey(7)
    x_star, server, clients, active, p_t = _agg_inputs(key)
    state = spec.init(server, active.shape[0])
    want = spec.aggregate(0, state, server, clients, x_star, active, p_t,
                          jnp.int32(0))
    got = spec.aggregate(0, state, server, clients, x_star, active, p_t,
                         jnp.int32(0), use_kernel=True)
    _assert_trees_equal(got, want)


def _family_batch_and_runners(scheme=SCHEME):
    task = get_traced_task(BASE)
    fed = BASE.cell_config(FAMILY[0], scheme)
    batch = make_cell_batch(BASE, fed, task, algos=FAMILY)
    r_xla = _runner_for(BASE, fed, task, METRIC_KEYS)
    r_ker = _runner_for(KSPEC, KSPEC.cell_config(FAMILY[0], scheme), task,
                        METRIC_KEYS)
    assert r_xla is not r_ker      # distinct traced programs, both cached
    return batch, r_xla, r_ker


def test_sweep_use_kernel_bit_for_bit():
    """All 4 family members x 2 lrs x 2 seeds x 5 rounds through the fused
    kernel: every leaf of the final states, per-round metrics and in-scan
    evals equals the XLA-path program bitwise (the interpret/CPU row of the
    dispatch layer's tolerance contract)."""
    batch, r_xla, r_ker = _family_batch_and_runners()
    _assert_trees_equal(r_ker(batch), r_xla(batch))


@multi_device
def test_sweep_use_kernel_sharded_bit_for_bit():
    """The fused-kernel program shards over the ("batch",) mesh like the
    XLA one: per-trajectory results equal the single-device kernel path AND
    the sharded XLA path bitwise."""
    batch, r_xla, r_ker = _family_batch_and_runners()
    mesh = resolve_batch_mesh()
    got = run_sharded(r_ker, batch, mesh)
    _assert_trees_equal(got, r_ker(batch))
    _assert_trees_equal(got, run_sharded(r_xla, batch, mesh))


def test_use_kernel_zero_extra_jit_entries(tmp_path, compiles_once):
    """The CI compile counter: a full 4-algorithm family run_sweep with
    use_kernel=True compiles exactly ONE (init, scan) jit entry — the fused
    program batches the whole family, adding zero entries over the XLA
    path's count."""
    spec = dataclasses.replace(KSPEC, rounds=3, eval_every=3,
                               algorithms=FAMILY, schemes=("bernoulli_ti",))
    cells = run_sweep(spec, metric_keys=METRIC_KEYS)
    assert [(c.algo, c.hparams["lr"]) for c in cells] == [
        (a, lr) for a in FAMILY for lr in spec.lrs]
    fed = spec.cell_config(FAMILY[0], "bernoulli_ti")
    runner = _runner_for(spec, fed, get_traced_task(spec), METRIC_KEYS)
    compiles_once(runner.init_batch, runner.scan_batch)
    # the kernel path is live, not decorative: distinct algorithms diverge
    finals = {c.algo: c.test_acc.tobytes() for c in cells
              if c.hparams["lr"] == spec.lrs[0]}
    assert len(set(finals.values())) == len(FAMILY)
    # and equals the XLA-path sweep cell for cell
    xspec = dataclasses.replace(spec, use_kernel=False)
    for kc, xc in zip(cells, run_sweep(xspec, metric_keys=METRIC_KEYS)):
        assert (kc.algo, kc.hparams) == (xc.algo, xc.hparams)
        np.testing.assert_array_equal(kc.test_acc, xc.test_acc)
        np.testing.assert_array_equal(kc.loss, xc.loss)


def test_spec_use_kernel_defers_to_env(monkeypatch):
    """SweepSpec.use_kernel=None resolves through the dispatch env default;
    the resolved value keys the runner cache."""
    import repro.experiments.grid as grid_mod

    spec = dataclasses.replace(BASE, rounds=2, eval_every=0)
    task = get_traced_task(spec)
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    r_off = _runner_for(spec, fed, task, METRIC_KEYS)
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    r_on = _runner_for(spec, fed, task, METRIC_KEYS)
    assert r_on is not r_off
    # explicit False pins the XLA path regardless of the env
    r_pinned = _runner_for(dataclasses.replace(spec, use_kernel=False), fed,
                           task, METRIC_KEYS)
    assert r_pinned is r_off
