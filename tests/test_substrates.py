"""Optimizers, data pipeline, checkpointing, sharding specs."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # bare image: fall back to seeded-random example cases
    HAVE_HYPOTHESIS = False

from repro.checkpointing import latest_step, restore, save
from repro.data import (
    dirichlet_partition,
    federated_classification_batches,
    federated_lm_batches,
    make_classification_data,
)
from repro.optim import adam, paper_decay, sgd


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make_opt", [lambda: sgd(0.1), lambda: sgd(0.1, 0.9),
                                      lambda: adam(0.05)])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    grad_fn = jax.grad(lambda p: 0.5 * jnp.sum(p["x"] ** 2))
    for _ in range(200):
        params, state = opt.update(params, state, grad_fn(params))
    assert float(jnp.linalg.norm(params["x"])) < 1e-2


def test_paper_decay_schedule():
    s = paper_decay(0.1)
    np.testing.assert_allclose(float(s(0)), 0.1)
    np.testing.assert_allclose(float(s(10)), 0.1 / np.sqrt(2.0), rtol=1e-6)
    assert float(s(1000)) < float(s(100)) < float(s(10))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_dirichlet_partition_volumes_and_skew():
    rng = np.random.default_rng(0)
    x, y = make_classification_data(0)
    idx, nu = dirichlet_partition(rng, y, num_clients=20, alpha=0.1, per_client=100)
    assert idx.shape == (20, 100)
    assert nu.shape == (20, 10)
    np.testing.assert_allclose(nu.sum(1), 1.0, rtol=1e-9)
    # alpha=0.1 -> strongly skewed: top class holds most of each client's mass
    assert np.median(nu.max(1)) > 0.5


def test_dirichlet_alpha_controls_heterogeneity():
    rng = np.random.default_rng(1)
    _, y = make_classification_data(1)
    _, nu_lo = dirichlet_partition(rng, y, 30, 0.1, 50)
    _, nu_hi = dirichlet_partition(rng, y, 30, 10.0, 50)
    assert nu_lo.max(1).mean() > nu_hi.max(1).mean() + 0.2


def test_classification_batches_shapes():
    rng = np.random.default_rng(2)
    x, y = make_classification_data(2)
    idx, _ = dirichlet_partition(rng, y, 8, 0.5, 64)
    b = federated_classification_batches(rng, x, y, idx, local_steps=3, batch_size=16)
    assert b["x"].shape == (8, 3, 16, x.shape[1])
    assert b["y"].shape == (8, 3, 16)
    assert set(np.unique(b["y"])) <= set(range(10))


def test_lm_batches_shapes():
    rng = np.random.default_rng(3)
    b = federated_lm_batches(rng, num_clients=4, local_steps=2, batch=2,
                             seq=16, vocab=100)
    assert b["tokens"].shape == (4, 2, 2, 16)
    np.testing.assert_array_equal(b["labels"][..., :-1], b["tokens"][..., 1:])
    assert b["tokens"].max() < 100


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)},
            "d": jnp.int32(7)}
    path = str(tmp_path / "ckpt")
    save(path, 3, tree)
    save(path, 10, tree)
    assert latest_step(path) == 10
    out = restore(path, 3, tree)
    np.testing.assert_allclose(np.asarray(out["a"]), np.arange(5.0))
    assert out["b"]["c"].shape == (2, 3)
    assert int(out["d"]) == 7


# ---------------------------------------------------------------------------
# sharding specs
# ---------------------------------------------------------------------------


def _check_spec_for_shape(dims):
    os.environ.setdefault("XLA_FLAGS", "")
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.specs import spec_for_shape
    mesh = make_host_mesh()
    spec = spec_for_shape(tuple(dims), mesh)
    assert len(spec) == len(dims)
    for dim, ax in zip(dims, spec):
        if ax is not None:
            assert dim % mesh.shape[ax] == 0


_SPEC_DIMS = [1, 2, 3, 16, 32, 64, 256, 1024, 4096]

if HAVE_HYPOTHESIS:

    @given(st.lists(st.sampled_from(_SPEC_DIMS), min_size=1, max_size=4))
    @settings(max_examples=60, deadline=None)
    def test_spec_for_shape_always_valid(dims):
        _check_spec_for_shape(dims)

else:
    _rng = np.random.default_rng(0)
    _SPEC_CASES = (
        [[1], [4096], [1, 1, 1, 1], [4096, 4096, 4096, 4096]]
        + [[int(_rng.choice(_SPEC_DIMS))
            for _ in range(int(_rng.integers(1, 5)))] for _ in range(56)]
    )

    @pytest.mark.parametrize("dims", _SPEC_CASES)
    def test_spec_for_shape_always_valid(dims):
        _check_spec_for_shape(dims)
