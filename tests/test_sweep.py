"""Vectorized sweep engine: the vmapped [S]-seed runner must be bit-for-bit
identical to S independent sequential ``make_run_rounds`` trajectories with
the same per-seed keys (mirrors ``tests/test_run_rounds.py``), and the
JSONL/npz results store must round-trip.

Shapes here (m=8, dim=16, hidden=16) are ones where XLA CPU compiles the
batched scan body with the same float reduction order as the unbatched one,
so equality is exact; at some other shapes CPU codegen can reassociate
reductions by 1 ulp (see ``make_vmap_run_rounds``'s docstring — the engine's
two-dispatch structure is what makes exactness attainable at all).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_run_rounds,
)
from repro.experiments import (
    ResultsStore,
    SweepSpec,
    eval_rounds,
    make_classification_task,
    make_vmap_run_rounds,
    run_cell,
    run_sweep,
    seed_keys,
    stack_seed_keys,
)
from repro.experiments.grid import _RUNNER_CACHE, seed_base_probs
from repro.optim import paper_decay, sgd

M, S_LOCAL, B = 8, 3, 4
SEEDS = (0, 1)
SPEC = SweepSpec(seeds=SEEDS, num_clients=M, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=B, local_steps=S_LOCAL)


def _task():
    return make_classification_task(
        data_seed=SPEC.data_seed, num_clients=M, dim=SPEC.dim,
        classes=SPEC.classes, hidden=SPEC.hidden, n_per_class=SPEC.n_per_class,
        n_train=SPEC.n_train, alpha=SPEC.alpha, per_client=SPEC.per_client,
        local_steps=S_LOCAL, batch_size=B)


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _sequential_reference(task, fed, algo, opt, p_base, num_rounds,
                          chunks=None):
    """S independent ``make_run_rounds`` trajectories with the engine's keys.

    ``chunks``: optional round-chunk lengths; when given, ``eval_test`` runs
    after every chunk (the sequential counterpart of in-scan eval cadence).
    """
    states, metrics, evals = [], [], []
    for i, seed in enumerate(SEEDS):
        ks = seed_keys(seed)
        link = make_link_process(p_base[i], fed)
        run_rounds = make_run_rounds(task.loss_fn, opt, algo, link, fed,
                                     task.source, donate=False)
        st = init_fed_state(ks["state"], task.init_params(ks["params"]), fed,
                            algo, link, opt)
        ds = task.source.init(ks["ds"])
        if chunks is None:
            st, ds, mets = run_rounds(st, ds, ks["data"], num_rounds)
            seed_evals = None
        else:
            collected, seed_evals = [], []
            for c in chunks:
                st, ds, mets_c = run_rounds(st, ds, ks["data"], c)
                collected.append(mets_c)
                seed_evals.append(task.eval_test(st.server))
            mets = jax.tree.map(lambda *xs: jnp.concatenate(xs), *collected)
        states.append(st)
        metrics.append(mets)
        evals.append(seed_evals)
    return states, metrics, evals


@pytest.mark.parametrize("algo_name,scheme", [
    ("fedpbc", "bernoulli_ti"),
    ("fedavg", "markov_hom"),
    ("mifa", "cyclic"),
])
def test_vmap_matches_sequential_bit_for_bit(algo_name, scheme):
    task = _task()
    fed = SPEC.cell_config(algo_name, scheme)
    algo = make_algorithm(fed)
    opt = sgd(paper_decay(SPEC.lr))
    K = 7

    runner = make_vmap_run_rounds(
        task.loss_fn, opt, algo, fed, task.source,
        link_factory=lambda p: make_link_process(p, fed),
        init_params=task.init_params, num_rounds=K)
    p_base = seed_base_probs(SPEC)
    states, out = runner(stack_seed_keys(SEEDS), p_base)

    seq_states, seq_metrics, _ = _sequential_reference(
        task, fed, algo, opt, p_base, K)
    for i in range(len(SEEDS)):
        _assert_trees_equal(jax.tree.map(lambda x: x[i], states),
                            seq_states[i])
        for k in seq_metrics[i]:
            np.testing.assert_array_equal(
                np.asarray(out["metrics"][k][i]),
                np.asarray(seq_metrics[i][k]))
    assert out["metrics"]["loss"].shape == (len(SEEDS), K)
    assert out["metrics"]["staleness"].shape == (len(SEEDS), K, M)


def test_vmap_eval_chunking_matches_chunked_sequential():
    """In-scan eval cadence (with a remainder tail: 7 = 3 + 3 + 1) must not
    perturb the trajectory, and evals must equal chunk-boundary evals of the
    sequential engine."""
    task = _task()
    fed = SPEC.cell_config("fedpbc", "bernoulli_ti")
    algo = make_algorithm(fed)
    opt = sgd(paper_decay(SPEC.lr))
    K, cadence = 7, 3

    runner = make_vmap_run_rounds(
        task.loss_fn, opt, algo, fed, task.source,
        link_factory=lambda p: make_link_process(p, fed),
        init_params=task.init_params, num_rounds=K,
        eval_every=cadence, eval_fn=task.eval_test)
    p_base = seed_base_probs(SPEC)
    states, out = runner(stack_seed_keys(SEEDS), p_base)

    assert eval_rounds(K, cadence) == [3, 6, 7]
    assert out["evals"].shape == (len(SEEDS), 3)
    assert out["metrics"]["loss"].shape == (len(SEEDS), K)

    seq_states, seq_metrics, seq_evals = _sequential_reference(
        task, fed, algo, opt, p_base, K, chunks=(3, 3, 1))
    for i in range(len(SEEDS)):
        _assert_trees_equal(jax.tree.map(lambda x: x[i], states),
                            seq_states[i])
        np.testing.assert_array_equal(
            np.asarray(out["metrics"]["loss"][i]),
            np.asarray(seq_metrics[i]["loss"]))
        np.testing.assert_array_equal(np.asarray(out["evals"][i]),
                                      np.asarray(jnp.stack(seq_evals[i])))


def test_eval_rounds_contract():
    """At least one eval, the last at num_rounds — for every edge case."""
    assert eval_rounds(7, 3) == [3, 6, 7]
    assert eval_rounds(6, 6) == [6]          # cadence == K: exactly one, at K
    assert eval_rounds(3, 5) == [3]          # cadence > K: one final eval
    assert eval_rounds(0, 3) == [0]          # K == 0: eval the initial model
    assert eval_rounds(5, 0) == [5]          # no cadence: single final eval
    assert eval_rounds(0, 0) == [0]
    for K, e in [(7, 3), (6, 6), (3, 5), (0, 3), (12, 4)]:
        rounds = eval_rounds(K, e)
        assert len(rounds) >= 1 and rounds[-1] == K


def test_eval_contract_num_rounds_zero_evals_initial_model():
    """K=0 with a cadence must return ONE eval (of the freshly initialized
    model) and zero-round metrics — not a zero-length eval axis that breaks
    every [S, E] consumer downstream."""
    task = _task()
    fed = SPEC.cell_config("fedpbc", "bernoulli_ti")
    runner = make_vmap_run_rounds(
        task.loss_fn, sgd(paper_decay(SPEC.lr)), make_algorithm(fed), fed,
        task.source, link_factory=lambda p: make_link_process(p, fed),
        init_params=task.init_params, num_rounds=0,
        eval_every=3, eval_fn=task.eval_test)
    states, out = runner(stack_seed_keys(SEEDS), seed_base_probs(SPEC))
    assert out["evals"].shape == (len(SEEDS), 1)
    assert out["metrics"]["loss"].shape == (len(SEEDS), 0)
    for i, seed in enumerate(SEEDS):
        init_params = task.init_params(seed_keys(seed)["params"])
        np.testing.assert_array_equal(
            np.asarray(out["evals"][i, 0]),
            np.asarray(task.eval_test(init_params)))
        _assert_trees_equal(jax.tree.map(lambda x: x[i], states.server),
                            init_params)

    # and through the executor: a rounds=0 cell yields [S, 1] evals and
    # [S, 0] per-round metrics
    import dataclasses
    spec0 = dataclasses.replace(SPEC, rounds=0, eval_every=2)
    cell = run_cell(spec0, "fedpbc", "bernoulli_ti")
    assert cell.eval_rounds == [0]
    assert cell.test_acc.shape == (len(SEEDS), 1)
    assert cell.loss.shape == (len(SEEDS), 0)
    assert cell.final_test().shape == (len(SEEDS),)


def test_eval_every_equals_num_rounds_fires_exactly_one_final_eval():
    """cadence == K: one eval, at round K, equal to the sequential final
    eval (not zero evals, not a duplicated final eval)."""
    task = _task()
    fed = SPEC.cell_config("fedpbc", "bernoulli_ti")
    algo = make_algorithm(fed)
    opt = sgd(paper_decay(SPEC.lr))
    K = 6
    runner = make_vmap_run_rounds(
        task.loss_fn, opt, algo, fed, task.source,
        link_factory=lambda p: make_link_process(p, fed),
        init_params=task.init_params, num_rounds=K,
        eval_every=K, eval_fn=task.eval_test)
    p_base = seed_base_probs(SPEC)
    states, out = runner(stack_seed_keys(SEEDS), p_base)
    assert out["evals"].shape == (len(SEEDS), 1)
    assert out["metrics"]["loss"].shape == (len(SEEDS), K)

    seq_states, _, seq_evals = _sequential_reference(
        task, fed, algo, opt, p_base, K, chunks=(K,))
    for i in range(len(SEEDS)):
        _assert_trees_equal(jax.tree.map(lambda x: x[i], states),
                            seq_states[i])
        np.testing.assert_array_equal(np.asarray(out["evals"][i]),
                                      np.asarray(jnp.stack(seq_evals[i])))


def test_results_store_roundtrip(tmp_path):
    store = ResultsStore(str(tmp_path / "sweeps"))
    acc = np.linspace(0.1, 0.9, 6).reshape(2, 3)
    rec0 = store.append({"suite": "t", "algo": "fedpbc", "scheme": "cyclic"},
                        arrays={"test_acc": acc})
    rec1 = store.append({"suite": "t", "algo": "fedavg", "scheme": "cyclic"})
    assert rec0["record_id"] == 0 and rec1["record_id"] == 1
    assert rec0["git_sha"]  # stamped (short sha or "unknown")

    rows = store.records(suite="t")
    assert [r["algo"] for r in rows] == ["fedpbc", "fedavg"]
    assert store.records(algo="fedpbc")[0]["scheme"] == "cyclic"
    np.testing.assert_array_equal(
        store.load_arrays(rows[0])["test_acc"], acc)
    assert store.load_arrays(rows[1]) == {}

    # a fresh handle on the same directory appends, never overwrites
    store2 = ResultsStore(str(tmp_path / "sweeps"))
    rec2 = store2.append({"suite": "t2"})
    assert rec2["record_id"] == 2
    with open(store2.path) as f:
        lines = [json.loads(l) for l in f if l.strip()]
    assert len(lines) == 3


def test_run_sweep_grid_and_compile_cache(tmp_path):
    import dataclasses
    spec = dataclasses.replace(SPEC, algorithms=("fedpbc", "fedavg"),
                               schemes=("bernoulli_ti",),
                               rounds=4, eval_every=2)
    store = ResultsStore(str(tmp_path / "sweeps"))
    cells = run_sweep(spec, store=store, suite="smoke")

    assert [(c.algo, c.scheme) for c in cells] == [
        ("fedpbc", "bernoulli_ti"), ("fedavg", "bernoulli_ti")]
    for cell in cells:
        assert cell.test_acc.shape == (len(SEEDS), 2)
        assert cell.train_acc.shape == (len(SEEDS),)
        assert cell.loss.shape == (len(SEEDS), 4)
        assert cell.eval_rounds == [2, 4]
        s = cell.summary()
        assert set(s) == {"test_acc", "train_acc"}
        assert s["test_acc"]["n"] == len(SEEDS)

    rows = store.records(suite="smoke")
    assert len(rows) == 2
    loaded = store.load_arrays(rows[0])
    np.testing.assert_array_equal(loaded["test_acc"], cells[0].test_acc)

    # Eq.-9 knobs (delta/sigma0) reach the compiled program only as traced
    # p_base inputs: a sweep differing ONLY in them must reuse the compiled
    # runner (no new cache entry)
    n_runners = len(_RUNNER_CACHE)
    spec_d = dataclasses.replace(spec, delta=0.1, sigma0=1.0,
                                 algorithms=("fedpbc",))
    cell_d = run_cell(spec_d, "fedpbc", "bernoulli_ti")
    assert len(_RUNNER_CACHE) == n_runners
    assert cell_d.test_acc.shape == (len(SEEDS), 2)


def test_sweep_throughput_bench_records_speedup():
    """The acceptance benchmark (m=32, S=8 on CPU) must record >= 2x
    cells/sec for the vmapped engine over the (same-protocol) sequential
    loop, and >= 2x for the traced hyperparameter ablation over the
    per-value-recompile path with a single compile serving every swept
    value. Regenerate with ``python -m benchmarks.run --only sweep``."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "out",
                        "sweep_throughput.json")
    if not os.path.exists(path):
        pytest.skip("benchmarks/out/sweep_throughput.json not generated yet")
    with open(path) as f:
        bench = json.load(f)
    assert bench["m"] == 32 and bench["n_seeds"] == 8
    assert bench["speedup"] >= 2.0, bench
    # the two arms run one protocol now: trajectories must agree
    assert bench["trajectory_max_abs_diff"] <= 1e-5, bench
    ab = bench["hparam_ablation"]
    assert ab["speedup"] >= 2.0, ab
    assert ab["trajectory_max_abs_diff"] <= 1e-5, ab
    if ab["traced_compile_entries"] >= 0:
        # one batched init + one batched scan serve the whole ablation;
        # the baked path compiles a pair per grid point
        assert ab["traced_compile_entries"] == 2, ab
        assert ab["per_value_compile_entries"] == 2 * ab["n_points"], ab
    # the algorithm axis: the whole fedavg family compiled ONCE (vs one
    # program per algorithm) and the switch-based program tracked the
    # per-algorithm path
    aa = bench["algo_axis"]
    assert aa["family"] == ["fedpbc", "fedavg", "fedavg_all",
                            "fedavg_known_p"], aa
    assert aa["trajectory_max_abs_diff"] <= 1e-5, aa
    if aa["batched_compile_programs"] >= 0:
        assert aa["batched_compile_programs"] == 1, aa
        assert aa["per_algo_compile_programs"] == len(aa["family"]), aa
    assert aa["speedup_cold"] > 1.0, aa
    # the device-scaling arm always records an entry; when it ran sharded,
    # the placement change must not have moved a single trajectory
    ds = bench["device_scaling"]
    assert ds["n_devices"] >= 1 and ds["single_device_cells_per_s"] > 0, ds
    if ds["n_devices"] > 1:
        assert ds["trajectory_max_abs_diff"] == 0.0, ds
        assert ds["sharded_cells_per_s"] > 0, ds
