"""Per-architecture smoke tests (deliverable f): a REDUCED variant of every
assigned arch family runs one forward and one federated train step on CPU,
asserting output shapes and the absence of NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, FederationConfig, get_config, reduced
from repro.core import init_fed_state, make_algorithm, make_link_process, make_round_fn
from repro.models.model import forward, init_params, loss_fn, make_cache, decode_step
from repro.optim import sgd


def _reduced(arch):
    return dataclasses.replace(reduced(get_config(arch)), dtype="float32")


def _memory_for(cfg, b):
    if cfg.family == "vlm":
        return 0.1 * jnp.ones((b, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        return 0.1 * jnp.ones((b, cfg.num_audio_frames, cfg.d_model))
    return None


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = _reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert not cfg.moe or cfg.moe.num_experts <= 4
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    logits, aux = forward(params, cfg, tokens, memory=_memory_for(cfg, B))
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert not np.isnan(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_federated_train_step(arch):
    """One FedPBC round over the reduced arch: loss finite, params move."""
    cfg = _reduced(arch)
    m, s, B, T = 2, 1, 2, 16
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(jnp.full((m,), 1.0), fed)  # always on
    opt = sgd(1e-2)

    def loss(params, batch):
        return loss_fn(params, cfg, batch, remat=False)

    rf = jax.jit(make_round_fn(loss, opt, algo, link, fed))
    params = init_params(jax.random.PRNGKey(0), cfg)
    st = init_fed_state(jax.random.PRNGKey(1), params, fed, algo, link, opt)
    toks = jax.random.randint(jax.random.PRNGKey(2), (m, s, B, T), 0, cfg.vocab_size)
    batches = {"tokens": toks, "labels": jnp.roll(toks, -1, -1)}
    mem = _memory_for(cfg, B)
    if mem is not None:
        batches["memory"] = jnp.broadcast_to(mem, (m, s) + mem.shape)
    st2, mets = rf(st, batches)
    assert np.isfinite(float(mets["loss"]))
    before = jax.tree.leaves(st.server)[0]
    after = jax.tree.leaves(st2.server)[0]
    assert not np.allclose(np.asarray(before), np.asarray(after))


@pytest.mark.parametrize("arch", ["rwkv6-3b", "mixtral-8x22b", "gemma2-9b",
                                  "seamless-m4t-medium"])
def test_decode_step_no_nan(arch):
    cfg = _reduced(arch)
    B = 2
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = make_cache(cfg, B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = decode_step(params, cfg, tok, cache, jnp.int32(0),
                                memory=_memory_for(cfg, B))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
