"""End-to-end behaviour tests: the full federated system on real (synthetic)
non-IID classification data with unreliable uplinks — a scaled-down Table 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FederationConfig
from repro.core import (
    build_base_probs,
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_round_fn,
)
from repro.data import (
    dirichlet_partition,
    federated_classification_batches,
    make_classification_data,
)
from repro.optim import sgd


def _mlp_init(key, dim, classes, hidden=32):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * hidden ** -0.5,
        "b2": jnp.zeros(classes),
    }


def _mlp_loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def _accuracy(params, x, y):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return float((jnp.argmax(logits, -1) == y).mean())


def _train(algo_name, scheme="bernoulli", time_varying=False, rounds=300,
           m=40, seed=1):
    from repro.optim import paper_decay
    rng = np.random.default_rng(seed)
    x_all, y_all = make_classification_data(seed, dim=32, n_per_class=500, sep=3.0)
    x, y = x_all[:4000], y_all[:4000]
    xt, yt = x_all[4000:], y_all[4000:]
    idx, nu = dirichlet_partition(rng, y, m, alpha=0.2, per_client=100)
    fed = FederationConfig(algorithm=algo_name, num_clients=m, local_steps=5,
                           scheme=scheme, time_varying=time_varying)
    # heterogeneous p tied to data mix, as in the paper (Eq. 9)
    p, _, _ = build_base_probs(jax.random.PRNGKey(seed), m, 10,
                               alpha=0.2, sigma0=6.0, delta=0.05)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    opt = sgd(paper_decay(0.1))
    rf = jax.jit(make_round_fn(_mlp_loss, opt, algo, link, fed))
    params = _mlp_init(jax.random.PRNGKey(seed + 1), 32, 10)
    st = init_fed_state(jax.random.PRNGKey(seed + 2), params, fed, algo, link, opt)
    for _ in range(rounds):
        batches = federated_classification_batches(
            rng, x, y, idx, local_steps=5, batch_size=32)
        st, mets = rf(st, {"x": jnp.asarray(batches["x"]),
                           "y": jnp.asarray(batches["y"])})
    return _accuracy(st.server, jnp.asarray(xt), jnp.asarray(yt))


def test_fedpbc_learns_under_bernoulli():
    acc = _train("fedpbc")
    assert acc > 0.72, acc


@pytest.mark.slow
def test_fedpbc_competitive_under_markov():
    acc = _train("fedpbc", scheme="markov")
    assert acc > 0.65, acc


@pytest.mark.slow
def test_fedpbc_vs_fedavg_all_table1_ordering():
    """Table 1's robust ordering: FedPBC beats FedAvg-all by a wide margin
    under non-uniform links (the full m=100 comparison lives in
    benchmarks/table1_accuracy.py)."""
    acc_pbc = _train("fedpbc")
    acc_all = _train("fedavg_all")
    assert acc_pbc >= acc_all + 0.2, (acc_pbc, acc_all)
