"""The algorithm axis of the batched sweep engine.

Acceptance guarantees of the AlgorithmSpec refactor:

1. Batching a whole state-compatible algorithm family (fedpbc / fedavg /
   fedavg_all / fedavg_known_p, all with empty ``AlgoState``) into ONE
   compiled program via a traced per-trajectory ``algo_id`` changes NOTHING
   per trajectory: every leaf equals the per-algorithm compiled path (a
   statically-bound single-``Algorithm`` runner, the pre-refactor execution
   model) bit for bit — on the single-device path and on a multi-device
   ``("batch",)`` mesh (CI runs this file under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
2. The executor's runner cache is keyed by the family's state structure, not
   the algorithm name: cells differing only in the (family-compatible)
   algorithm share one runner and ONE (init, scan) jit entry each — the CI
   compile counter.
3. Mixed-state grids fall back to one program per family with unchanged
   result ordering.
4. ``SweepSpec`` rejects empty axes, duplicate seeds, and unknown names at
   construction with the offending field named.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import make_algorithm, make_link_process
from repro.core.algorithms import AlgorithmSpec, algo_family, state_signature
from repro.experiments import SweepSpec, ResultsStore, run_sweep
from repro.experiments.grid import (
    _RUNNER_CACHE,
    _run_batch,
    _runner_for,
    get_traced_task,
    make_cell_batch,
    run_cell_batch,
)
from repro.experiments.shard import resolve_batch_mesh, run_sharded
from repro.experiments.sweep import make_batched_run_rounds
from repro.optim import paper_decay, sgd

N_DEV = len(jax.devices())
multi_device = pytest.mark.skipif(
    N_DEV < 2,
    reason="needs >1 device (XLA_FLAGS=--xla_force_host_platform_device_count=8)")

SEEDS = (0, 1)
BASE = SweepSpec(seeds=SEEDS, num_clients=8, dim=16, hidden=16, classes=10,
                 n_per_class=60, n_train=480, per_client=24,
                 batch_size=4, local_steps=3, rounds=5, eval_every=2,
                 lrs=(0.05, 0.1))
METRIC_KEYS = ("loss", "num_active")
FAMILY = algo_family("fedavg")


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _per_algorithm_reference(spec, algo, scheme):
    """The pre-refactor per-algorithm compiled path: a runner built over ONE
    statically-bound Algorithm (direct branch dispatch, no switch) running
    this algorithm's own (point x seed) batch with no algorithm axis."""
    task = get_traced_task(spec)
    fed = spec.cell_config(algo, scheme)
    runner = make_batched_run_rounds(
        task.loss_fn, make_algorithm(fed), fed,
        optimizer_factory=lambda hp: sgd(paper_decay(hp["lr"])),
        link_factory=lambda p, hp: make_link_process(
            p, fed, gamma=hp["gamma"], period=hp["period"]),
        source_factory=task.source_factory,
        init_params=task.init_params,
        num_rounds=spec.rounds, eval_every=spec.eval_every,
        eval_fn=task.eval_test, metric_keys=METRIC_KEYS)
    batch = dataclasses.replace(
        make_cell_batch(spec, fed, task), algo_id=())
    return runner(batch)


def test_family_is_the_paper_baseline_quartet():
    assert FAMILY == ("fedpbc", "fedavg", "fedavg_all", "fedavg_known_p")
    for name in FAMILY:
        assert algo_family(name) == FAMILY
        assert state_signature(name) == frozenset()
    # singleton families: distinct state signatures never co-batch
    assert algo_family("fedau") == ("fedau",)
    assert algo_family("mifa") == ("mifa",)
    assert algo_family("f3ast") == ("f3ast",)
    assert algo_family("fedpbc_m") == ("fedpbc_m",)


def test_family_batch_matches_per_algorithm_bit_for_bit():
    """All 4 family members x 2 lrs x 2 seeds in ONE switch-based program vs
    four per-algorithm statically-dispatched programs: states (including the
    unified algo_state), per-round metrics, and in-scan evals must be
    bitwise identical per trajectory."""
    scheme = "bernoulli_tv"     # time-varying p_t exercises the known-p branch
    task = get_traced_task(BASE)
    fed = BASE.cell_config(FAMILY[0], scheme)
    runner = _runner_for(BASE, fed, task, METRIC_KEYS)
    batch = make_cell_batch(BASE, fed, task, algos=FAMILY)
    P, S = len(BASE.hparam_points()), len(SEEDS)
    assert batch.batch_size == len(FAMILY) * P * S
    np.testing.assert_array_equal(
        np.asarray(batch.algo_id), np.repeat(np.arange(4), P * S))
    states, out = runner(batch)

    for ai, algo in enumerate(FAMILY):
        ref_states, ref_out = _per_algorithm_reference(BASE, algo, scheme)
        rows = slice(ai * P * S, (ai + 1) * P * S)
        _assert_trees_equal(jax.tree.map(lambda x: x[rows], states),
                            ref_states)
        _assert_trees_equal(jax.tree.map(lambda x: x[rows], out), ref_out)


@multi_device
def test_family_batch_sharded_bit_for_bit():
    """The joint (algo x point x seed) axis shards over the ("batch",) mesh
    like any other batch: switch-based aggregation under GSPMD partitioning
    must equal the single-device family program bitwise."""
    scheme = "bernoulli_tv"
    task = get_traced_task(BASE)
    fed = BASE.cell_config(FAMILY[0], scheme)
    runner = _runner_for(BASE, fed, task, METRIC_KEYS)
    batch = make_cell_batch(BASE, fed, task, algos=FAMILY)
    mesh = resolve_batch_mesh()
    ref = runner(batch)                          # single-device
    sharded = run_sharded(runner, batch, mesh)   # padded + partitioned
    _assert_trees_equal(sharded, ref)


def test_runner_cache_keyed_by_family_not_algorithm_name(compiles_once):
    """Cells differing only in a family-compatible algorithm share ONE
    runner object and ONE compiled (init, scan) pair."""
    spec = dataclasses.replace(BASE, rounds=4, eval_every=0)
    task = get_traced_task(spec)
    runners = {a: _runner_for(spec, spec.cell_config(a, "bernoulli_ti"),
                              task, METRIC_KEYS) for a in FAMILY}
    assert len({id(r) for r in runners.values()}) == 1
    a = run_cell_batch(spec, "fedpbc", "bernoulli_ti",
                       metric_keys=METRIC_KEYS, mesh=None)
    b = run_cell_batch(spec, "fedavg", "bernoulli_ti",
                       metric_keys=METRIC_KEYS, mesh=None)
    # same compiled program served both (same batch shapes, different algo_id
    # values — a traced input, not a compile knob)
    runner = runners["fedpbc"]
    compiles_once(runner.init_batch, runner.scan_batch)
    # and the trajectories genuinely differ by algorithm
    assert not np.array_equal(a[0].test_acc, b[0].test_acc)


def test_run_sweep_batches_family_into_one_program(tmp_path, compiles_once):
    """A FedPBC-vs-baselines sweep (the paper's core comparison) executes as
    ONE compiled program — the CI compile counter — while cells and store
    rows keep the scheme -> algorithm -> point order with the algo
    coordinate recorded."""
    spec = dataclasses.replace(BASE, rounds=3, eval_every=3,
                               algorithms=FAMILY,
                               schemes=("bernoulli_ti",))
    store = ResultsStore(str(tmp_path / "sweeps"))
    cells = run_sweep(spec, store=store, suite="algo-axis",
                      metric_keys=METRIC_KEYS)
    P = len(spec.hparam_points())
    assert [(c.algo, c.hparams["lr"]) for c in cells] == [
        (a, lr) for a in FAMILY for lr in spec.lrs]
    fed = spec.cell_config(FAMILY[0], "bernoulli_ti")
    runner = _runner_for(spec, fed, get_traced_task(spec), METRIC_KEYS)
    # the whole 4-algorithm family reused ONE jit cache entry per stage
    compiles_once(runner.init_batch, runner.scan_batch)
    rows = store.records(suite="algo-axis")
    assert [r["algo"] for r in rows] == [a for a in FAMILY for _ in range(P)]
    for row, cell in zip(rows, cells):
        np.testing.assert_array_equal(store.load_arrays(row)["test_acc"],
                                      cell.test_acc)
    # distinct algorithms produced distinct trajectories (the algo_id input
    # is wired, not decorative)
    finals = {c.algo: c.test_acc.tobytes() for c in cells if
              c.hparams["lr"] == spec.lrs[0]}
    assert len(set(finals.values())) == len(FAMILY)


def test_mixed_state_grid_falls_back_per_family():
    """fedpbc (empty state) + fedau (gap stats) cannot share a program: the
    sweep falls back to one runner per family, with per-algorithm results
    identical to their own single-cell runs — and the INTERLEAVED spec order
    (fedpbc, fedau, fedavg) preserved even though fedpbc/fedavg executed
    together as one group."""
    spec = dataclasses.replace(BASE, rounds=3, eval_every=0, lrs=(),
                               algorithms=("fedpbc", "fedau", "fedavg"),
                               schemes=("bernoulli_ti",))
    task = get_traced_task(spec)
    r_pbc = _runner_for(spec, spec.cell_config("fedpbc", "bernoulli_ti"),
                        task, METRIC_KEYS)
    r_au = _runner_for(spec, spec.cell_config("fedau", "bernoulli_ti"),
                       task, METRIC_KEYS)
    assert r_pbc is not r_au
    cells = run_sweep(spec, metric_keys=METRIC_KEYS)
    assert [c.algo for c in cells] == ["fedpbc", "fedau", "fedavg"]
    for cell in cells:
        solo = run_cell_batch(spec, cell.algo, "bernoulli_ti",
                              metric_keys=METRIC_KEYS)[0]
        np.testing.assert_array_equal(cell.test_acc, solo.test_acc)
        np.testing.assert_array_equal(cell.loss, solo.loss)


def test_run_sweep_persists_completed_groups_before_later_failures(
        tmp_path, monkeypatch):
    """Store rows of an already-finished family group must survive a crash in
    a later group (e.g. mifa's [m, ...] memory OOMing): persistence is
    incremental per group — INCLUDING results the spec-order emission gate
    was still holding back behind the crashed family (fedavg here ran
    together with fedpbc but is spec-ordered after fedau)."""
    import repro.experiments.grid as grid_mod

    spec = dataclasses.replace(BASE, rounds=3, eval_every=0, lrs=(),
                               algorithms=("fedpbc", "fedau", "fedavg"),
                               schemes=("bernoulli_ti",))
    real = grid_mod._run_batch

    def failing(spec_, algos, scheme, **kw):
        if "fedau" in algos:
            raise RuntimeError("simulated OOM in fedau group")
        return real(spec_, algos, scheme, **kw)

    monkeypatch.setattr(grid_mod, "_run_batch", failing)
    store = ResultsStore(str(tmp_path / "sweeps"))
    with pytest.raises(RuntimeError, match="simulated OOM"):
        run_sweep(spec, store=store, suite="crash", metric_keys=METRIC_KEYS)
    assert [r["algo"] for r in store.records(suite="crash")] == [
        "fedpbc", "fedavg"]


def test_mixed_family_sweep_does_not_thrash_sharded_batch_cache():
    """Alternating family groups across schemes must keep ONE committed copy
    of the heavy batch arrays per group (sub-entries under one (spec, mesh)
    base), not evict and re-commit each other once per (scheme, family)."""
    from repro.experiments.grid import _SHARDED_BATCH_CACHE

    spec = dataclasses.replace(BASE, rounds=3, eval_every=0, lrs=(),
                               algorithms=("fedpbc", "fedau"),
                               schemes=("bernoulli_ti", "bernoulli_tv"))
    run_sweep(spec, metric_keys=METRIC_KEYS, devices=jax.devices()[:1])
    assert len(_SHARDED_BATCH_CACHE) == 1            # one (spec, mesh) base
    (entry,) = _SHARDED_BATCH_CACHE.values()
    assert set(entry["groups"]) == {("fedpbc",), ("fedau",)}
    # ONE committed dataset copy serves every group (device_put of an
    # already-committed array is a no-op, so the sub-entries alias it)
    for sharded, _ in entry["groups"].values():
        for base_leaf, group_leaf in zip(jax.tree.leaves(entry["shared"]),
                                         jax.tree.leaves(sharded.shared)):
            assert group_leaf is base_leaf


def test_unified_state_container_shapes():
    """Unused AlgoState leaves are zero-sized; fields only some members of a
    (hypothetical mixed) spec need are materialized for all of them."""
    server = {"w": jnp.ones((3, 2)), "b": jnp.zeros(2)}
    m = 5
    empty = AlgorithmSpec(FAMILY).init(server, m)
    assert empty.gap.shape == (0,) and empty.lam.shape == (0,)
    assert jax.tree.leaves(empty.mem)[0].shape[0] == 0
    assert jax.tree.leaves(empty.mom)[0].shape[0] == 0

    au = AlgorithmSpec(("fedau",)).init(server, m)
    assert au.gap.shape == au.sum_gaps.shape == au.n_gaps.shape == (m,)
    assert au.lam.shape == (0,)

    mi = AlgorithmSpec(("mifa",)).init(server, m)
    assert {l.shape[:1] for l in jax.tree.leaves(mi.mem)} == {(m,)}

    mixed = AlgorithmSpec(("fedavg", "fedau")).init(server, m)
    assert mixed.gap.shape == (m,)      # masked (inert) for fedavg rows


def test_algorithm_spec_validation_and_binding():
    with pytest.raises(ValueError, match="non-empty"):
        AlgorithmSpec(())
    with pytest.raises(ValueError, match="unknown algorithms.*fedx"):
        AlgorithmSpec(("fedpbc", "fedx"))
    with pytest.raises(ValueError, match="duplicates"):
        AlgorithmSpec(("fedpbc", "fedpbc"))
    with pytest.raises(ValueError, match="unknown algorithm"):
        state_signature("fedx")
    spec = AlgorithmSpec(FAMILY)
    assert spec.id_of("fedavg_all") == 2
    with pytest.raises(ValueError, match="not in this spec's family"):
        spec.id_of("mifa")
    assert spec.bind(1).name == "fedavg"
    assert spec.bind(3).needs_p            # fedavg_known_p
    # mixing families in one batch is refused before anything compiles
    task = get_traced_task(BASE)
    fed = BASE.cell_config("fedpbc", "bernoulli_ti")
    with pytest.raises(ValueError, match="state-compatible"):
        make_cell_batch(BASE, fed, task, algos=("fedpbc", "mifa"))
    with pytest.raises(ValueError, match="state-compatible"):
        _run_batch(BASE, ("fedpbc", "fedau"), "bernoulli_ti",
                   metric_keys=METRIC_KEYS)


def test_sweep_spec_validation_names_offending_field():
    with pytest.raises(ValueError, match="SweepSpec.algorithms is empty"):
        dataclasses.replace(BASE, algorithms=())
    with pytest.raises(ValueError, match="SweepSpec.schemes is empty"):
        dataclasses.replace(BASE, schemes=())
    with pytest.raises(ValueError, match="SweepSpec.seeds is empty"):
        dataclasses.replace(BASE, seeds=())
    with pytest.raises(ValueError, match=r"SweepSpec.seeds.*duplicate.*\[3\]"):
        dataclasses.replace(BASE, seeds=(0, 3, 3))
    with pytest.raises(ValueError,
                       match="SweepSpec.algorithms.*duplicates.*fedpbc"):
        dataclasses.replace(BASE, algorithms=("fedpbc", "fedavg", "fedpbc"))
    with pytest.raises(ValueError,
                       match="SweepSpec.schemes.*duplicates.*cyclic"):
        dataclasses.replace(BASE, schemes=("cyclic", "cyclic"))
    with pytest.raises(ValueError, match="SweepSpec.algorithms.*'fedxyz'"):
        dataclasses.replace(BASE, algorithms=("fedpbc", "fedxyz"))
    with pytest.raises(ValueError, match="SweepSpec.schemes.*'carrier'"):
        dataclasses.replace(BASE, schemes=("bernoulli_ti", "carrier"))
    # a valid spec still constructs
    dataclasses.replace(BASE, algorithms=("mifa",), seeds=(5,))
