"""MoE dispatch: scatter vs einsum equivalence + capacity semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.moe import _capacity, moe_apply, moe_init


def _cfg(dispatch="einsum", cf=1.25, experts=4, top_k=2):
    base = dataclasses.replace(reduced(get_config("mixtral-8x22b")), dtype="float32")
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, dispatch=dispatch,
                                      capacity_factor=cf,
                                      num_experts=experts, top_k=top_k))


@pytest.mark.parametrize("cf", [0.5, 1.25, 4.0])
@pytest.mark.parametrize("topk", [1, 2])
def test_scatter_equals_einsum(cf, topk):
    cfg = _cfg(cf=cf, top_k=topk)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 40, cfg.d_model))
    y1, a1 = moe_apply(p, x, _cfg("einsum", cf, top_k=topk))
    y2, a2 = moe_apply(p, x, _cfg("scatter", cf, top_k=topk))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)


def test_capacity_drop_monotone():
    """Lower capacity factor -> more dropped tokens -> smaller output norm."""
    cfg_lo = _cfg(cf=0.25)
    cfg_hi = _cfg(cf=8.0)
    p = moe_init(jax.random.PRNGKey(2), cfg_lo)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg_lo.d_model))
    y_lo, _ = moe_apply(p, x, cfg_lo)
    y_hi, _ = moe_apply(p, x, cfg_hi)
    assert float(jnp.linalg.norm(y_lo)) < float(jnp.linalg.norm(y_hi))


def test_aux_loss_near_one_for_uniform_router():
    """Switch aux loss == 1 exactly under a perfectly balanced router."""
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 512, cfg.d_model))
    _, aux = moe_apply(p, x, cfg)
    assert 0.8 < float(aux) < 1.6  # near-uniform random router


def test_capacity_formula():
    cfg = _cfg(cf=1.25, experts=4, top_k=2)
    assert _capacity(cfg, 64) == int(1.25 * 2 * 64 / 4)
