"""Cross-session results tooling: store merge (dedup by cell key, git-SHA
report) and figure-curve CSV export — both must work from stored artifacts
alone, re-running nothing."""
import numpy as np

from repro.experiments.plots import export_curves, main as plots_main
from repro.experiments.results import (
    ResultsStore,
    cell_key,
    group_by_sha,
    main as results_main,
)


def _rec(suite, algo, seeds, sha, acc):
    return ({"suite": suite, "algo": algo, "scheme": "bernoulli_ti",
             "seeds": seeds, "rounds": 4, "eval_every": 2,
             "hparams": {"lr": 0.1, "alpha": 0.1}, "eval_rounds": [2, 4],
             "git_sha": sha},
            {"test_acc": np.asarray(acc),
             "loss": np.linspace(1.0, 0.5, len(seeds) * 4).reshape(
                 len(seeds), 4)})


def test_cell_key_identity():
    rec_a, _ = _rec("t1", "fedpbc", [0, 1], "aaa", [[0.1, 0.2], [0.2, 0.3]])
    rec_b, _ = _rec("t1", "fedpbc", [0, 1], "bbb", [[0.5, 0.6], [0.6, 0.7]])
    assert cell_key(rec_a) == cell_key(rec_b)       # sha not part of identity
    rec_c, _ = _rec("t1", "fedpbc", [2], "aaa", [[0.1, 0.2]])
    assert cell_key(rec_a) != cell_key(rec_c)       # seeds are
    rec_d = dict(rec_a, hparams={"lr": 0.2, "alpha": 0.1})
    assert cell_key(rec_a) != cell_key(rec_d)       # hparam coords are
    # protocol fields in the recorded spec are part of the identity: an m=32
    # run must never deduplicate against an m=100 run of the same suite
    rec_e = dict(rec_a, spec={"num_clients": 32})
    rec_f = dict(rec_a, spec={"num_clients": 100})
    assert cell_key(rec_e) != cell_key(rec_f)
    # sweep-grid bookkeeping in the spec (which other cells ran alongside)
    # does NOT split identity
    rec_g = dict(rec_a, spec={"num_clients": 32,
                              "algorithms": ["fedpbc", "fedavg"]})
    assert cell_key(rec_e) == cell_key(rec_g)
    # legacy pre-hparams records: the swept value only lives in the spec's
    # scalar knobs, which must still separate ablation rows
    legacy_a = {k: v for k, v in rec_a.items() if k != "hparams"}
    old1 = dict(legacy_a, spec={"delta": 0.001})
    old2 = dict(legacy_a, spec={"delta": 0.1})
    assert cell_key(old1) != cell_key(old2)


def test_cell_key_search_rows_do_not_collide():
    """Adaptive-search rows carry a (rung, budget) coordinate: a candidate
    pruned early and the same hyperparameter point run at another budget are
    different measurements and must not dedup under merge — while records
    WITHOUT a search dict (every pre-search row) keep their exact keys."""
    base, _ = _rec("asha", "fedpbc", [0, 1], "aaa", [[0.1, 0.2], [0.2, 0.3]])
    pruned = dict(base, search={"rung": 0, "budget_rounds": 3,
                                "status": "pruned"})
    finished = dict(base, search={"rung": 1, "budget_rounds": 6,
                                  "status": "finished"})
    assert cell_key(pruned) != cell_key(finished)
    # status alone is bookkeeping, not identity: same budget coordinate
    # (e.g. "stopped" vs "finished" at the cap) still dedups
    stopped = dict(finished, search=dict(finished["search"],
                                         status="stopped"))
    assert cell_key(stopped) == cell_key(finished)
    # legacy rows: absent search dict == empty search dict
    assert cell_key(base) == cell_key(dict(base, search={}))


def test_summarize_ignores_nan_padding():
    from repro.experiments.results import summarize

    s = summarize([0.5, float("nan"), 0.7])
    assert s["n"] == 2
    assert s["mean"] == np.mean([0.5, 0.7])
    # all-NaN degenerates like the empty case
    assert summarize([float("nan")])["n"] == 0


def test_merge_dedupes_by_cell_key_later_store_wins(tmp_path):
    a = ResultsStore(str(tmp_path / "a"))
    rec, arrays = _rec("t1", "fedpbc", [0, 1], "aaa",
                       [[0.1, 0.2], [0.2, 0.3]])
    a.append(rec, arrays=arrays)
    rec2, arrays2 = _rec("t1", "fedavg", [0, 1], "aaa",
                         [[0.3, 0.4], [0.4, 0.5]])
    a.append(rec2, arrays=arrays2)

    b = ResultsStore(str(tmp_path / "b"))
    rerun, rerun_arrays = _rec("t1", "fedpbc", [0, 1], "bbb",
                               [[0.8, 0.9], [0.7, 0.8]])
    b.append(rerun, arrays=rerun_arrays)

    merged = ResultsStore.merge(str(tmp_path / "m"), str(tmp_path / "a"), b)
    rows = merged.records()
    assert len(rows) == 2
    assert [r["record_id"] for r in rows] == [0, 1]
    by_algo = {r["algo"]: r for r in rows}
    # the fedpbc cell appears in both stores: the later store's row survives,
    # with its arrays and its recorded SHA
    assert by_algo["fedpbc"]["git_sha"] == "bbb"
    np.testing.assert_array_equal(
        merged.load_arrays(by_algo["fedpbc"])["test_acc"],
        np.asarray([[0.8, 0.9], [0.7, 0.8]]))
    assert by_algo["fedavg"]["git_sha"] == "aaa"
    assert {r["source_record_id"] for r in rows} == {0, 1}

    groups = group_by_sha(rows)
    assert {sha: len(g) for sha, g in groups.items()} == {"aaa": 1, "bbb": 1}


def test_merge_dedups_legacy_and_hparam_records_of_same_cell(tmp_path):
    """A pre-hyperparameter-axis record (no ``hparams`` field — its coords
    live only in the spec's scalar knobs) and a new record of the SAME cell
    must share one ``cell_key``, so a re-run under the new engine supersedes
    the legacy row instead of duplicating it — while legacy rows at OTHER
    coordinates survive as their own cells."""
    coords = {"lr": 0.1, "gamma": 0.5, "alpha": 0.1, "sigma0": 10.0,
              "delta": 0.02}
    spec = dict(coords, num_clients=8, local_steps=5)
    base = {"suite": "fig8", "algo": "fedpbc", "scheme": "bernoulli_ti",
            "seeds": [0, 1], "rounds": 4, "eval_every": 2, "spec": spec}

    legacy = dict(base, git_sha="old")                      # no "hparams"
    modern = dict(base, hparams=dict(coords), git_sha="new")
    assert cell_key(legacy) == cell_key(modern)
    legacy_other = dict(base, git_sha="old",
                        spec=dict(spec, delta=0.1))         # other ablation pt
    assert cell_key(legacy_other) != cell_key(legacy)

    old_store = ResultsStore(str(tmp_path / "old"))
    old_store.append(legacy, arrays={"test_acc": np.asarray([[0.1, 0.2],
                                                             [0.2, 0.3]])})
    old_store.append(legacy_other)
    new_store = ResultsStore(str(tmp_path / "new"))
    new_store.append(modern, arrays={"test_acc": np.asarray([[0.8, 0.9],
                                                             [0.7, 0.8]])})

    merged = ResultsStore.merge(str(tmp_path / "m"), old_store, new_store)
    rows = merged.records()
    assert len(rows) == 2
    by_sha = group_by_sha(rows)
    assert {sha: len(g) for sha, g in by_sha.items()} == {"old": 1, "new": 1}
    # the deduped cell keeps the NEW record's payload; the surviving legacy
    # row is the other ablation point
    np.testing.assert_array_equal(
        merged.load_arrays(by_sha["new"][0])["test_acc"],
        np.asarray([[0.8, 0.9], [0.7, 0.8]]))
    assert by_sha["old"][0]["spec"]["delta"] == 0.1


def test_merge_survives_missing_npz(tmp_path, capsys):
    import os
    a = ResultsStore(str(tmp_path / "a"))
    rec = a.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    a.append(*_rec("t1", "fedavg", [0], "aaa", [[0.2, 0.3]]))
    os.remove(os.path.join(a.root, rec["arrays"]))   # partially copied store

    merged = ResultsStore.merge(str(tmp_path / "m"), a)
    rows = merged.records()
    assert len(rows) == 2                            # metadata survives
    by_algo = {r["algo"]: r for r in rows}
    assert "arrays" not in by_algo["fedpbc"]         # payload was missing
    assert merged.load_arrays(by_algo["fedavg"])["test_acc"].shape == (1, 2)
    assert "skipping arrays" in capsys.readouterr().err


def test_merge_refuses_nonempty_destination(tmp_path):
    """Re-running merge with the same --out must not silently duplicate
    rows; a non-empty destination is refused (re-merge via a fresh dir)."""
    a = ResultsStore(str(tmp_path / "a"))
    a.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    ResultsStore.merge(str(tmp_path / "m"), a)
    import pytest
    with pytest.raises(ValueError, match="already has records"):
        ResultsStore.merge(str(tmp_path / "m"), a)
    # re-merge path: old destination as a source into a fresh dir
    merged2 = ResultsStore.merge(str(tmp_path / "m2"), str(tmp_path / "m"), a)
    assert len(merged2.records()) == 1
    # a typo'd source path fails loudly instead of contributing zero rows
    with pytest.raises(FileNotFoundError, match="no results.jsonl"):
        ResultsStore.merge(str(tmp_path / "m3"), str(tmp_path / "nope"), a)


def test_merge_cli_reports_by_sha(tmp_path, capsys):
    a = ResultsStore(str(tmp_path / "a"))
    a.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    b = ResultsStore(str(tmp_path / "b"))
    b.append(*_rec("t2", "fedavg", [0], "bbb", [[0.2, 0.3]]))
    results_main(["merge", str(tmp_path / "a"), str(tmp_path / "b"),
                  "--out", str(tmp_path / "m")])
    out = capsys.readouterr().out
    assert "merged 2 stores" in out and "(2 rows)" in out
    assert "git aaa: 1 rows (t1=1)" in out
    assert "git bbb: 1 rows (t2=1)" in out


def test_export_curves_pools_seed_batches(tmp_path):
    store = ResultsStore(str(tmp_path / "s"))
    store.append(*_rec("t1", "fedpbc", [0, 1], "aaa",
                       [[0.1, 0.2], [0.2, 0.3]]))
    # a second session's batch of DIFFERENT seeds for the same curve
    rec, arrays = _rec("t1", "fedpbc", [2, 3], "aaa",
                       [[0.3, 0.4], [0.4, 0.5]])
    store.append(rec, arrays=arrays)

    written = export_curves(store, str(tmp_path / "curves"), suite="t1")
    acc = [p for p in written if p.endswith("_acc.csv")]
    loss = [p for p in written if p.endswith("_loss.csv")]
    assert len(acc) == 1 and len(loss) == 1

    with open(acc[0]) as f:
        lines = [l.strip() for l in f]
    assert lines[0] == "round,mean,std,ci95,n_seeds"
    assert len(lines) == 3                       # eval_rounds [2, 4]
    r2 = lines[1].split(",")
    assert r2[0] == "2" and r2[4] == "4"         # pooled over 4 seeds
    np.testing.assert_allclose(float(r2[1]), np.mean([0.1, 0.2, 0.3, 0.4]))
    with open(loss[0]) as f:
        assert len(f.readlines()) == 5           # header + K=4 rounds


def test_export_curves_reruns_supersede_not_double_count(tmp_path):
    """The store is append-only: a re-run of the SAME cell (same seeds) must
    replace, not pool — pooling duplicate seeds would shrink the CI."""
    store = ResultsStore(str(tmp_path / "s"))
    store.append(*_rec("t1", "fedpbc", [0, 1], "aaa",
                       [[0.1, 0.2], [0.2, 0.3]]))
    store.append(*_rec("t1", "fedpbc", [0, 1], "bbb",   # re-run, new code
                       [[0.5, 0.6], [0.6, 0.7]]))
    written = export_curves(store, str(tmp_path / "curves"))
    acc = [p for p in written if p.endswith("_acc.csv")]
    assert len(acc) == 1
    with open(acc[0]) as f:
        lines = [l.strip() for l in f]
    r2 = lines[1].split(",")
    assert r2[4] == "2"                              # still 2 seeds, not 4
    np.testing.assert_allclose(float(r2[1]), np.mean([0.5, 0.6]))  # latest


def test_interleaved_handles_keep_record_ids_unique(tmp_path):
    """Two live handles on one root must never hand out the same record_id
    (the per-handle count cache is invalidated by file growth)."""
    a = ResultsStore(str(tmp_path / "s"))
    b = ResultsStore(str(tmp_path / "s"))
    ids = [a.append({"suite": "t"})["record_id"],
           b.append({"suite": "t"})["record_id"],
           b.append({"suite": "t"})["record_id"],
           a.append({"suite": "t"})["record_id"]]
    assert ids == [0, 1, 2, 3]


def test_export_curves_overlapping_seed_batches_dedup(tmp_path):
    """Seed batches that OVERLAP (e.g. [0,1] then a superset re-run [0,1,2])
    must not double-count shared seeds; the later record's rows win."""
    store = ResultsStore(str(tmp_path / "s"))
    store.append(*_rec("t1", "fedpbc", [0, 1], "aaa",
                       [[0.1, 0.2], [0.2, 0.3]]))
    rec, arrays = _rec("t1", "fedpbc", [0, 1, 2], "bbb",
                       [[0.5, 0.6], [0.6, 0.7], [0.7, 0.8]])
    arrays["loss"] = np.linspace(1.0, 0.5, 12).reshape(3, 4)
    store.append(rec, arrays=arrays)
    written = export_curves(store, str(tmp_path / "curves"))
    acc = [p for p in written if p.endswith("_acc.csv")][0]
    with open(acc) as f:
        lines = [l.strip() for l in f]
    r2 = lines[1].split(",")
    assert r2[4] == "3"                              # 3 unique seeds, not 5
    np.testing.assert_allclose(float(r2[1]), np.mean([0.5, 0.6, 0.7]))


def test_export_curves_skips_missing_npz(tmp_path, capsys):
    import os
    store = ResultsStore(str(tmp_path / "s"))
    rec = store.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    store.append(*_rec("t1", "fedavg", [0], "aaa", [[0.2, 0.3]]))
    os.remove(os.path.join(store.root, rec["arrays"]))
    written = export_curves(store, str(tmp_path / "curves"))
    assert len(written) == 2                         # fedavg curve survives
    assert all("fedavg" in p for p in written)
    assert "missing arrays" in capsys.readouterr().err


def test_export_curves_arrayless_rerun_supersedes_stale_arrays(tmp_path,
                                                               capsys):
    """A later record WITHOUT an array payload (merge keeps metadata when an
    npz was lost) must supersede an older same-cell record — the stale old
    arrays must not be exported as the cell's current curve."""
    store = ResultsStore(str(tmp_path / "s"))
    store.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    rerun, _ = _rec("t1", "fedpbc", [0], "bbb", [[0.9, 0.9]])
    store.append(rerun)                              # no arrays payload
    written = export_curves(store, str(tmp_path / "curves"))
    assert written == []                             # nothing stale exported
    assert "no array payload" in capsys.readouterr().err


def test_export_curves_protocol_variants_get_distinct_files(tmp_path):
    """Curves differing only in protocol fields (e.g. num_clients) must not
    overwrite each other's CSVs."""
    store = ResultsStore(str(tmp_path / "s"))
    rec, arrays = _rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]])
    store.append(dict(rec, spec={"num_clients": 32}), arrays=arrays)
    store.append(dict(rec, spec={"num_clients": 100}), arrays=arrays)
    written = export_curves(store, str(tmp_path / "curves"))
    assert len(written) == len(set(written)) == 4    # 2 curves x (acc, loss)


def test_export_curves_close_floats_get_distinct_files(tmp_path):
    """hparams differing only beyond %g display precision (logspace-style
    lrs) must still map to distinct CSVs (exact values live in the digest)."""
    store = ResultsStore(str(tmp_path / "s"))
    rec, arrays = _rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]])
    store.append(dict(rec, hparams={"lr": 0.012345678}), arrays=arrays)
    store.append(dict(rec, hparams={"lr": 0.012345681}), arrays=arrays)
    written = export_curves(store, str(tmp_path / "curves"))
    assert len(written) == len(set(written)) == 4


def test_plots_cli(tmp_path, capsys):
    store = ResultsStore(str(tmp_path / "s"))
    store.append(*_rec("t1", "fedpbc", [0], "aaa", [[0.1, 0.2]]))
    plots_main(["--store", str(tmp_path / "s"),
                "--out", str(tmp_path / "curves")])
    out = capsys.readouterr().out
    assert "2 curve files" in out
