"""Fig. 5/6 analogue: ASCII traces of the six unreliable-uplink schemes —
plus a cross-device arm: FedPBC at m=10,000 clients with a C=256 on-device
cohort per round and buffered semi-async aggregation (``repro.scale``).

The whole T-round trace of each scheme is produced by one ``jax.lax.scan``
over ``link.sample`` — the same device-side pattern the multi-round engine
uses — instead of T Python-loop dispatches. The cross-device arm runs the
real round engine: clients are stateless (``FedState.clients`` is ``()``,
so no [m, n_params] tensor exists), each round trains only the sampled
cohort, and the server commits its buffer when it fills or the deadline
passes.

  PYTHONPATH=src python examples/unreliable_links_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import make_link_process

SCHEMES = [
    ("bernoulli, time-invariant", dict(scheme="bernoulli")),
    ("bernoulli, time-varying", dict(scheme="bernoulli", time_varying=True)),
    ("markov, homogeneous", dict(scheme="markov")),
    ("markov, non-homogeneous", dict(scheme="markov", time_varying=True)),
    ("cyclic, no reset", dict(scheme="cyclic", cyclic_length=40)),
    ("cyclic, periodic reset", dict(scheme="cyclic", cyclic_length=40,
                                    cyclic_reset=True)),
]

P = jnp.asarray([0.05, 0.1, 0.5, 0.9])
T = 80


def trace(link, T: int, key) -> np.ndarray:
    """[T, m] bool activity matrix from a single scanned dispatch."""

    def body(carry, t):
        state, key = carry
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, t, k)
        return (state, key), active

    init = (link.init(jax.random.PRNGKey(0)), key)
    _, actives = jax.lax.scan(body, init, jnp.arange(T, dtype=jnp.int32))
    return np.asarray(actives)


def cross_device_arm(m=10_000, C=256, rounds=12):
    """FedPBC over m clients, C-cohort rounds, buffered aggregation."""
    from repro.core import init_fed_state, make_run_rounds
    from repro.core.algorithms import make_algorithm_spec
    from repro.data import fixed_source
    from repro.optim import sgd
    from repro.scale import BUFFER_METRIC_KEYS, Strategy

    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=2)
    spec = make_algorithm_spec(("fedpbc",), fed)
    link = make_link_process(jnp.full((m,), 0.5), fed)
    loss = lambda params, batch: jnp.sum(
        (params["x"] - batch["u"].mean()) ** 2)
    opt = sgd(0.05)
    source = fixed_source({"u": jnp.zeros((m, fed.local_steps, 4))})
    strat = Strategy("buffered", buffer_size=C // 2, deadline_rounds=3)
    run = make_run_rounds(loss, opt, spec, link, fed, source,
                          metric_keys=("loss", "num_active")
                          + BUFFER_METRIC_KEYS,
                          donate=False, strategy=strat, cohort_size=C)
    st = init_fed_state(jax.random.PRNGKey(0), {"x": jnp.ones(8)}, fed,
                        spec, link, opt, stateless_clients=True,
                        buffered=True)
    st, _, mets = run(st, source.init(jax.random.PRNGKey(2)),
                      jax.random.PRNGKey(3), rounds)
    print(f"\n== cross-device: fedpbc, m={m:,}, cohort C={C}, "
          f"buffer={strat.buffer_size}, deadline={strat.deadline_rounds} ==")
    assert st.clients == ()            # stateless: O(C) round memory
    commit = np.asarray(mets["commit"])
    fill = np.asarray(mets["buffer_fill"])
    for t in range(rounds):
        bar = "#" * int(fill[t] * 30 / max(fill.max(), 1))
        mark = " COMMIT" if commit[t] else ""
        print(f"  round {t:2d} |{bar:<30s}| fill={int(fill[t]):4d}{mark}")
    print(f"  commits={int(np.asarray(st.buffer.commits))}, "
          f"final loss={float(np.asarray(mets['loss'])[-1]):.4f}")


if __name__ == "__main__":
    for name, kw in SCHEMES:
        fed = FederationConfig(num_clients=len(P), **kw)
        link = make_link_process(P, fed)
        actives = trace(link, T, jax.random.PRNGKey(1))
        print(f"\n== {name} ==")
        for i in range(len(P)):
            row = "".join("#" if a else "." for a in actives[:, i])
            print(f"  p={float(P[i]):4.2f} |{row}|")
    cross_device_arm()
