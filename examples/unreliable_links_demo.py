"""Fig. 5/6 analogue: ASCII traces of the six unreliable-uplink schemes.

  PYTHONPATH=src python examples/unreliable_links_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import make_link_process

SCHEMES = [
    ("bernoulli, time-invariant", dict(scheme="bernoulli")),
    ("bernoulli, time-varying", dict(scheme="bernoulli", time_varying=True)),
    ("markov, homogeneous", dict(scheme="markov")),
    ("markov, non-homogeneous", dict(scheme="markov", time_varying=True)),
    ("cyclic, no reset", dict(scheme="cyclic", cyclic_length=40)),
    ("cyclic, periodic reset", dict(scheme="cyclic", cyclic_length=40,
                                    cyclic_reset=True)),
]

P = jnp.asarray([0.05, 0.1, 0.5, 0.9])
T = 80

if __name__ == "__main__":
    for name, kw in SCHEMES:
        fed = FederationConfig(num_clients=len(P), **kw)
        link = make_link_process(P, fed)
        state = link.init(jax.random.PRNGKey(0))
        key = jax.random.PRNGKey(1)
        rows = [[] for _ in P]
        for t in range(T):
            key, k = jax.random.split(key)
            active, p_t, state = link.sample(state, jnp.int32(t), k)
            for i, a in enumerate(np.asarray(active)):
                rows[i].append("#" if a else ".")
        print(f"\n== {name} ==")
        for i, r in enumerate(rows):
            print(f"  p={float(P[i]):4.2f} |{''.join(r)}|")
