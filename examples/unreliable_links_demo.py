"""Fig. 5/6 analogue: ASCII traces of the six unreliable-uplink schemes.

The whole T-round trace of each scheme is produced by one ``jax.lax.scan``
over ``link.sample`` — the same device-side pattern the multi-round engine
uses — instead of T Python-loop dispatches.

  PYTHONPATH=src python examples/unreliable_links_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import make_link_process

SCHEMES = [
    ("bernoulli, time-invariant", dict(scheme="bernoulli")),
    ("bernoulli, time-varying", dict(scheme="bernoulli", time_varying=True)),
    ("markov, homogeneous", dict(scheme="markov")),
    ("markov, non-homogeneous", dict(scheme="markov", time_varying=True)),
    ("cyclic, no reset", dict(scheme="cyclic", cyclic_length=40)),
    ("cyclic, periodic reset", dict(scheme="cyclic", cyclic_length=40,
                                    cyclic_reset=True)),
]

P = jnp.asarray([0.05, 0.1, 0.5, 0.9])
T = 80


def trace(link, T: int, key) -> np.ndarray:
    """[T, m] bool activity matrix from a single scanned dispatch."""

    def body(carry, t):
        state, key = carry
        key, k = jax.random.split(key)
        active, _, state = link.sample(state, t, k)
        return (state, key), active

    init = (link.init(jax.random.PRNGKey(0)), key)
    _, actives = jax.lax.scan(body, init, jnp.arange(T, dtype=jnp.int32))
    return np.asarray(actives)


if __name__ == "__main__":
    for name, kw in SCHEMES:
        fed = FederationConfig(num_clients=len(P), **kw)
        link = make_link_process(P, fed)
        actives = trace(link, T, jax.random.PRNGKey(1))
        print(f"\n== {name} ==")
        for i in range(len(P)):
            row = "".join("#" if a else "." for a in actives[:, i])
            print(f"  p={float(P[i]):4.2f} |{row}|")
