"""End-to-end driver: federated training of a transformer LM with FedPBC
under unreliable uplinks — data pipeline, round engine, checkpointing.

Thin wrapper over the production launcher so the example stays honest:

  PYTHONPATH=src python examples/train_federated_lm.py \
      --arch smollm-135m --rounds 100 --clients 8 --scheme markov
"""
from repro.launch.train import main

if __name__ == "__main__":
    main()
