"""Batched serving example: greedy decoding with per-family caches
(KV ring buffers for SWA archs, RWKV/SSM states for recurrent ones).

  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b --gen 24
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
