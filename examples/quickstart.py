"""Quickstart: the paper's Fig.-3 quadratic counterexample in ~40 lines.

Two client populations with very different uplink probabilities (0.9 vs 0.1).
FedAvg converges to a biased point (Prop. 1); FedPBC's postponed broadcast
(implicit gossiping) removes the bias.

All 400 rounds run as ONE device dispatch: ``fixed_source`` holds the batch
on device and ``make_run_rounds`` scans the round function (see README,
"Multi-round scan engine").

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import FederationConfig
from repro.core import init_fed_state, make_algorithm, make_link_process, make_run_rounds
from repro.core.bias import fedavg_fixed_point
from repro.data import fixed_source
from repro.optim import sgd

M, D, S, ROUNDS, ETA = 20, 16, 10, 400, 2e-3

key = jax.random.PRNGKey(0)
u = (jnp.arange(M) / M)[:, None] + 0.1 * jax.random.normal(key, (M, D))
x_star = u.mean(0)                                  # the true optimum
p = jnp.where(jnp.arange(M) < M // 2, 0.9, 0.1)     # heterogeneous uplinks


def run(algorithm: str) -> float:
    fed = FederationConfig(algorithm=algorithm, num_clients=M, local_steps=S)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    loss = lambda params, batch: 0.5 * jnp.sum((params["x"] - batch["u"]) ** 2)
    opt = sgd(ETA)
    source = fixed_source({"u": jnp.broadcast_to(u[:, None], (M, S, D))})
    run_rounds = make_run_rounds(loss, opt, algo, link, fed, source)
    state = init_fed_state(jax.random.PRNGKey(1), {"x": jnp.zeros(D)},
                           fed, algo, link, opt)
    state, _, metrics = run_rounds(state, source.init(jax.random.PRNGKey(2)),
                                   jax.random.PRNGKey(3), ROUNDS)
    assert metrics["loss"].shape == (ROUNDS,)       # stacked per-round metrics
    return float(jnp.linalg.norm(state.server["x"] - x_star))


if __name__ == "__main__":
    import numpy as np
    err_avg = run("fedavg")
    err_pbc = run("fedpbc")
    predicted_bias = float(np.linalg.norm(
        fedavg_fixed_point(np.asarray(p), np.asarray(u)) - np.asarray(x_star)))
    print(f"||x - x*||  FedAvg : {err_avg:.4f}   (Eq.-3 predicted bias "
          f"{predicted_bias:.4f})")
    print(f"||x - x*||  FedPBC : {err_pbc:.4f}   <- implicit gossiping wins")
    assert err_pbc < 0.5 * err_avg
