"""Table 2: first round to reach 1/4, 1/2, 3/4, 1 of the best test accuracy
under Bernoulli time-varying links.

The per-round eval trajectory comes from the batched sweep core's in-scan
eval cadence (``evals [S, E]`` at ``eval_rounds`` boundaries), so the whole
7-algorithm column runs as 7 compiled programs total — no per-eval host
round-trips. Like table 1 it occupies a single point on the engine's
hyperparameter axis; its compiled programs are shared with any lr/alpha
ablation of the same protocol."""
from __future__ import annotations

import numpy as np

from repro.experiments import SweepSpec, run_sweep

from benchmarks.common import ALGOS


def run(csv=True, *, rounds=300, m=100, algos=ALGOS, seed=0, store=None):
    spec = SweepSpec(algorithms=tuple(algos), schemes=("bernoulli_tv",),
                     seeds=(seed,), rounds=rounds,
                     eval_every=min(10, rounds), num_clients=m)
    cells = run_sweep(spec, store=store, suite="table2")
    trajs = {c.algo: list(zip(c.eval_rounds, c.test_acc.mean(axis=0)))
             for c in cells}
    best = max(a for tr in trajs.values() for _, a in tr)
    targets = [best * f for f in (0.25, 0.5, 0.75, 1.0)]
    if csv:
        print("table2,algo,q25_round,q50_round,q75_round,q100_round,best_acc")
    out = {}
    for algo, tr in trajs.items():
        firsts = []
        for tgt in targets:
            hit = next((r for r, a in tr if a >= tgt - 1e-9), None)
            firsts.append(hit if hit is not None else -1)
        out[algo] = firsts
        if csv:
            print(f"table2,{algo},{firsts[0]},{firsts[1]},{firsts[2]},"
                  f"{firsts[3]},{best:.4f}", flush=True)
    return out


if __name__ == "__main__":
    run()
