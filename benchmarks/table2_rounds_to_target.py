"""Table 2: first round to reach 1/4, 1/2, 3/4, 1 of the best test accuracy
under Bernoulli time-varying links.

The per-round eval trajectory comes from the batched sweep core's in-scan
eval cadence (``evals [S, E]`` at ``eval_rounds`` boundaries), so the whole
7-algorithm column runs as 7 compiled programs total — no per-eval host
round-trips. Like table 1 it occupies a single point on the engine's
hyperparameter axis; its compiled programs are shared with any lr/alpha
ablation of the same protocol.

Besides the CSV view, ``run`` emits a machine-readable rounds-to-target
baseline — a ``BENCH {...}`` JSON line, also written to ``out_path``
(default ``benchmarks/out/table2_rounds_to_target.json``) — with the
absolute accuracy targets and the first round each algorithm reached them.
``benchmarks/asha.py`` consumes this file as the exhaustive-search baseline
its adaptive-search time-to-target claim is measured against.
"""
from __future__ import annotations

import json
import os

from repro.experiments import SweepSpec, run_sweep

from benchmarks.common import ALGOS

OUT_PATH = os.path.join(os.path.dirname(__file__), "out",
                        "table2_rounds_to_target.json")


def run(csv=True, *, rounds=300, m=100, algos=ALGOS, seed=0, store=None,
        out_path=OUT_PATH):
    spec = SweepSpec(algorithms=tuple(algos), schemes=("bernoulli_tv",),
                     seeds=(seed,), rounds=rounds,
                     eval_every=min(10, rounds), num_clients=m)
    cells = run_sweep(spec, store=store, suite="table2")
    trajs = {c.algo: list(zip(c.eval_rounds, c.test_acc.mean(axis=0)))
             for c in cells}
    best = max(a for tr in trajs.values() for _, a in tr)
    fractions = (0.25, 0.5, 0.75, 1.0)
    targets = [best * f for f in fractions]
    if csv:
        print("table2,algo,q25_round,q50_round,q75_round,q100_round,best_acc")
    firsts_by_algo = {}
    for algo, tr in trajs.items():
        firsts = []
        for tgt in targets:
            hit = next((r for r, a in tr if a >= tgt - 1e-9), None)
            firsts.append(int(hit) if hit is not None else -1)
        firsts_by_algo[algo] = firsts
        if csv:
            print(f"table2,{algo},{firsts[0]},{firsts[1]},{firsts[2]},"
                  f"{firsts[3]},{best:.4f}", flush=True)
    result = {
        "bench": "table2_rounds_to_target",
        "m": m,
        "rounds": rounds,
        "seeds": [seed],
        "scheme": "bernoulli_tv",
        "eval_every": min(10, rounds),
        "algos": list(algos),
        "best_acc": float(best),
        "fractions": list(fractions),
        "targets": [float(t) for t in targets],
        # first eval round at which each algorithm's seed-mean trajectory
        # reached each target (-1: never within the budget)
        "rounds_to_target": firsts_by_algo,
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    return result


if __name__ == "__main__":
    run()
