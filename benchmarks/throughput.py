"""Round-throughput: scanned multi-round engine vs per-round dispatch.

Measures wall-clock for the acceptance workload (m=32 clients, synthetic
2-layer MLP, 200 rounds, bernoulli links) on two execution paths sharing the
same jit-ed round step and the same device-resident ``DataSource``:

- ``loop``: one dispatch per round from Python (``run_rounds_loop``) — the
  pre-refactor execution model;
- ``scan``: all rounds inside one ``jax.lax.scan`` (``make_run_rounds``).

Prints a ``BENCH {...}`` JSON line and writes it to
``benchmarks/out/throughput.json``. The refactor's acceptance bar is
``speedup >= 2``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs import FederationConfig
from repro.core import (
    build_base_probs,
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_round_fn,
    make_round_step,
    make_run_rounds,
    run_rounds_loop,
)
from repro.data import classification_source, dirichlet_partition, make_classification_data
from repro.optim import paper_decay, sgd

from benchmarks.common import mlp_init, mlp_loss


def _setup(m, seed):
    rng = np.random.default_rng(seed)
    x, y = make_classification_data(seed, dim=32, n_per_class=600, sep=3.0)
    idx, _ = dirichlet_partition(rng, y, m, alpha=0.1, per_client=64)
    fed = FederationConfig(algorithm="fedpbc", num_clients=m, local_steps=5)
    p, _, _ = build_base_probs(jax.random.PRNGKey(seed), m, 10)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    opt = sgd(paper_decay(0.1))
    source = classification_source(x, y, idx, local_steps=5, batch_size=32)

    def init_states(seed):
        params = mlp_init(jax.random.PRNGKey(seed + 1))
        st = init_fed_state(jax.random.PRNGKey(seed + 2), params, fed, algo,
                            link, opt)
        return st, source.init(jax.random.PRNGKey(seed + 3))

    return fed, algo, link, opt, source, init_states


def run(csv=True, *, rounds=200, m=32, seed=0, out_path=None):
    fed, algo, link, opt, source, init_states = _setup(m, seed)
    data_key = jax.random.PRNGKey(seed + 4)
    round_fn = make_round_fn(mlp_loss, opt, algo, link, fed)
    # one jitted step shared by warm-up and timed run, so the timed region
    # measures dispatch only (a fresh closure would recompile inside it)
    step = jax.jit(make_round_step(round_fn, source))
    run_rounds = make_run_rounds(mlp_loss, opt, algo, link, fed, source)

    # warm up both compile caches on the measured shapes, then time fresh runs
    st, ds = init_states(seed)
    st, ds, _ = run_rounds_loop(st, ds, data_key, 2, round_fn=round_fn,
                                source=source, step=step)
    st, ds = init_states(seed)
    run_rounds(st, ds, data_key, rounds)

    st, ds = init_states(seed)
    t0 = time.perf_counter()
    st, ds, mets = run_rounds_loop(st, ds, data_key, rounds,
                                   round_fn=round_fn, source=source, step=step)
    jax.block_until_ready(st.server)
    loop_s = time.perf_counter() - t0
    loop_loss = float(mets["loss"][-1])

    st, ds = init_states(seed)
    t0 = time.perf_counter()
    st, ds, mets = run_rounds(st, ds, data_key, rounds)
    jax.block_until_ready(st.server)
    scan_s = time.perf_counter() - t0
    scan_loss = float(mets["loss"][-1])

    result = {
        "bench": "round_throughput",
        "m": m,
        "rounds": rounds,
        "local_steps": 5,
        "model": "mlp_32x64x10",
        "loop_seconds": round(loop_s, 4),
        "scan_seconds": round(scan_s, 4),
        "loop_rounds_per_s": round(rounds / loop_s, 2),
        "scan_rounds_per_s": round(rounds / scan_s, 2),
        "speedup": round(loop_s / scan_s, 2),
        "final_loss_loop": round(loop_loss, 6),
        "final_loss_scan": round(scan_loss, 6),
        "backend": jax.default_backend(),
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "out",
                                "throughput.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=32)
    a = ap.parse_args()
    run(rounds=a.rounds, m=a.clients)
