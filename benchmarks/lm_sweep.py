"""LM-sweep throughput: the federated LM family on the 2-D ("batch", "model")
mesh vs the same program on one device, roofline-gated.

The workload is the PR-8 tentpole: a smollm-class reduced transformer as the
client model, the fedpbc/fedavg/fedavg_all/fedavg_known_p family x swept lrs
as ONE compiled program (traced lr axis, switch-based algorithm axis), the
flattened (point x seed) trajectory batch sharded over ``"batch"`` and each
trajectory's parameters/optimizer state sharded over ``"model"``
(``repro.experiments.shard.run_sharded_2d``). Three arms:

- ``lm_family``: warm rounds/sec of the family sweep, single-device vs the
  2-D mesh, with the max per-trajectory deviation measured and gated at
  float32-ulp scale (clients land whole on "model" shards and updates are
  gathered before any cross-client reduction, so the aggregation adds no
  divergence; the pinned ``tests/test_lm_sweep.py`` shapes are exactly
  bitwise, while at other shapes XLA CPU fusion at per-device client
  shapes can reassociate a reduction by ~1 ulp — the JSON reports the
  exact measured diff and a ``bitwise`` flag).
- ``roofline``: the 2-D program's compiled ``cost_analysis()`` + HLO
  collective bytes fed to ``repro.launch.roofline.Roofline`` — reports the
  achieved fraction of speed-of-light (``useful_fraction`` = model flops
  6*N*tokens over total HLO flops) and the bottleneck term. All terms are
  per round: XLA's cost analysis charges the scanned loop body once.
- ``cohort``: the cross-device scale path at LM size — m=10k clients,
  C=256 cohort, stateless client state — on the same 2-D mesh.

Honesty note on the speedup column: with forced host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``) all "devices"
SHARE the box's physical cores, so on a single-core host the sharded arm
measures partitioning overhead, not scaling — the JSON records
``host_cores`` next to ``speedup`` so the number can be read in context.
On a real multi-device backend (or a multi-core host) the same program
scales with the batch axis. Bitwise equality holds either way and is the
gate that matters.

Prints a ``BENCH {...}`` JSON line; full mode writes
``benchmarks/out/lm_sweep.json``. ``--smoke`` runs a seconds-scale config
and does NOT overwrite the committed JSON.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

if __name__ == "__main__":
    # must precede the first jax import to take effect
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core.algorithms import algo_family
from repro.experiments import SweepSpec
from repro.experiments.grid import (
    _runner_for,
    get_traced_task,
    make_cell_batch,
)
from repro.experiments.shard import pad_batch, shard_batch
from repro.launch.mesh import make_2d_mesh
from repro.launch.roofline import Roofline, collective_stats

METRIC_KEYS = ("loss", "num_active")


def _timed(fn):
    jax.block_until_ready(fn())           # compile + warm
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    return time.perf_counter() - t0, out


def _tree_max_abs_diff(a, b) -> float:
    return max(
        float(np.abs(np.asarray(x, np.float64)
                     - np.asarray(y, np.float64)).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        if np.asarray(x).size)


def _param_count(task) -> int:
    shapes = jax.eval_shape(task.init_params, jax.random.key(0))
    return sum(int(l.size) for l in jax.tree.leaves(shapes))


def _tokens_per_round(spec: SweepSpec, batch_size_B: int) -> int:
    """Global training tokens one ROUND consumes: B trajectories x active
    clients x local steps x batch x seq. Per round, not per program, because
    ``compiled.cost_analysis()`` is trip-count-agnostic — it charges the
    scan's while-loop body ONCE — so the useful-flops numerator must count
    one body execution too or ``useful_fraction`` inflates by ``rounds``."""
    m_active = spec.cohort_size if spec.cohort_size else spec.num_clients
    return (batch_size_B * m_active * spec.local_steps
            * spec.batch_size * spec.lm_seq)


def _throughput_arm(spec: SweepSpec, algos, mesh, *, with_roofline=False):
    """Warm single-device vs 2-D-mesh execution of one family cell batch.
    Returns the arm's BENCH sub-dict (plus a roofline sub-dict when asked)."""
    task = get_traced_task(spec)
    fed = spec.cell_config(algos[0], "bernoulli_ti")
    batch = make_cell_batch(spec, fed, task, algos=algos)
    B = batch.batch_size
    total_rounds = B * spec.rounds

    plain = _runner_for(spec, fed, task, METRIC_KEYS)
    single_s, ref = _timed(lambda: plain(batch))
    entry = {
        "algos": list(algos),
        "lrs": list(spec.lrs),
        "n_trajectories": B,
        "rounds": spec.rounds,
        "num_clients": spec.num_clients,
        "cohort_size": spec.cohort_size,
        "single_device_seconds": round(single_s, 4),
        "single_device_rounds_per_s": round(total_rounds / single_s, 4),
    }
    if mesh is None:
        entry["note"] = ("single device visible; rerun under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 (CPU) or "
                         "on a multi-device backend for the 2-D arm")
        return entry

    r2d = _runner_for(spec, fed, task, METRIC_KEYS, shard_mesh=mesh)
    # commit the padded sharded batch ONCE outside the timed region (the
    # production path run_sharded_2d/_sharded_cell_batch memoizes this
    # transfer; the single-device arm's batch is already device-resident)
    padded, b_real = pad_batch(batch, mesh.shape["batch"])
    sharded = shard_batch(padded, mesh)
    sharded_s, out = _timed(lambda: r2d(sharded))
    if padded.batch_size != b_real:
        out = jax.tree.map(lambda x: x[:b_real], out)
    # the 2-D placement must not change the trajectories: state + evals are
    # gated at float32-ulp scale (the pinned tests/test_lm_sweep.py shapes
    # are exactly 0.0; at other shapes XLA CPU may fuse per-client
    # forward/backward differently at per-device client shapes and
    # reassociate a reduction by ~1 ulp — see make_batched_run_rounds).
    # The exact measured diffs are reported, not just the gate.
    diff = _tree_max_abs_diff((ref[0], ref[1]["evals"]),
                              (out[0], out[1]["evals"]))
    metrics_diff = _tree_max_abs_diff(ref[1]["metrics"], out[1]["metrics"])
    if diff > 1e-6:
        raise RuntimeError(
            f"2-D-mesh and single-device trajectories diverged: {diff}")
    if metrics_diff > 1e-5:
        raise RuntimeError(
            f"2-D-mesh loss telemetry diverged beyond ulp scale: "
            f"{metrics_diff}")
    entry.update({
        "mesh": dict(mesh.shape),
        "padded_trajectories": padded.batch_size,
        "sharded_seconds": round(sharded_s, 4),
        "sharded_rounds_per_s": round(total_rounds / sharded_s, 4),
        "speedup": round(single_s / sharded_s, 2),
        "trajectory_max_abs_diff": diff,
        "metrics_max_abs_diff": metrics_diff,
        "bitwise": bool(diff == 0.0 and metrics_diff == 0.0),
    })
    if with_roofline:
        entry["roofline"] = _roofline(spec, r2d, sharded, task,
                                      chips=mesh.size,
                                      batch_size_B=padded.batch_size)
    return entry


def _roofline(spec, r2d, sharded, task, *, chips, batch_size_B):
    """Lower the 2-D scan program, pull flops/bytes from the compiled
    cost_analysis and collective bytes from the partitioned HLO, and score
    the achieved fraction of speed-of-light (6*N*tokens useful flops over
    total HLO flops) on the v5e hardware model. All terms are per ROUND:
    XLA's cost analysis charges the scanned while-loop body once (verified:
    identical flops at rounds=2 and rounds=8), so tokens are counted for
    one round to match."""
    st, ds = r2d.init_batch(sharded.keys, sharded.p_base, sharded.hparams,
                            sharded.data, sharded.shared, sharded.algo_id)
    compiled = r2d.scan_batch.lower(
        st, ds, sharded.keys["data"], sharded.p_base, sharded.hparams,
        sharded.shared, sharded.algo_id).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per program
        cost = cost[0]
    coll = collective_stats(compiled.as_text())
    n_params = _param_count(task)
    rf = Roofline(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=float(coll.total_bytes),
        chips=chips,
        model_flops=6.0 * n_params * _tokens_per_round(spec, batch_size_B))
    row = rf.row()
    row["param_count"] = n_params
    row["coll_count"] = dict(coll.count_by_kind)
    return row


def run(csv=True, *, rounds=10, smoke=False, out_path=None):
    n_dev = len(jax.devices())
    mesh = make_2d_mesh(4, 2, jax.devices()[:8]) if n_dev >= 8 else None
    family = algo_family("fedavg")

    if smoke:
        rounds = 2
        lm = SweepSpec(algorithms=family, schemes=("bernoulli_ti",),
                       seeds=(0,), rounds=rounds, eval_every=rounds,
                       num_clients=4, local_steps=1, batch_size=1,
                       per_client=8, lrs=(0.1,), task="lm", lm_d_model=32,
                       lm_layers=1, lm_seq=16, classes=4, lm_n_seqs=64,
                       lm_n_test=16)
        cohort = dataclasses.replace(
            lm, algorithms=family[:2], num_clients=64, cohort_size=8,
            per_client=4)
    else:
        lm = SweepSpec(algorithms=family, schemes=("bernoulli_ti",),
                       seeds=(0,), rounds=rounds,
                       eval_every=max(rounds // 2, 1), num_clients=4,
                       local_steps=2, batch_size=2, per_client=16,
                       lrs=(0.05, 0.1), task="lm", lm_d_model=64,
                       lm_layers=2, lm_seq=32, classes=4, lm_n_seqs=256,
                       lm_n_test=64)
        cohort = dataclasses.replace(
            lm, algorithms=family[:2], lrs=(0.05, 0.1),
            rounds=max(rounds // 2, 2), eval_every=max(rounds // 2, 2),
            num_clients=10_000, cohort_size=256, per_client=4,
            local_steps=1, lm_n_seqs=512)

    lm_family = _throughput_arm(lm, family, mesh, with_roofline=True)
    cohort_arm = _throughput_arm(cohort, tuple(cohort.algorithms), mesh)

    result = {
        "bench": "lm_sweep",
        "smoke": smoke,
        "arch": lm.lm_arch,
        "d_model": lm.lm_d_model,
        "layers": lm.lm_layers,
        "seq_len": lm.lm_seq,
        "n_devices": n_dev,
        # forced host devices share these physical cores: read `speedup`
        # against host_cores (1 core -> the sharded arm measures overhead,
        # not scaling; bitwise equality is the invariant that transfers)
        "host_cores": os.cpu_count(),
        "lm_family": lm_family,
        "cohort": cohort_arm,
        "backend": jax.default_backend(),
    }
    print("BENCH " + json.dumps(result), flush=True)
    if not smoke:
        if out_path is None:
            out_path = os.path.join(os.path.dirname(__file__), "out",
                                    "lm_sweep.json")
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale config; no JSON file written")
    a = ap.parse_args()
    run(rounds=a.rounds, smoke=a.smoke)
