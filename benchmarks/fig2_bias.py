"""Fig. 2: expected FedAvg output vs p2 for the 2-client scalar example
(u1=0, u2=100, p1=0.5). Analytic — validates Eq. (3) visually."""
from __future__ import annotations

import numpy as np

from repro.core.bias import fedavg_fixed_point, two_client_fixed_point


def run(csv=True):
    rows = []
    for p2 in np.linspace(0.05, 1.0, 20):
        closed = two_client_fixed_point(0.0, 100.0, 0.5, p2)
        series = fedavg_fixed_point(np.array([0.5, p2]),
                                    np.array([[0.0], [100.0]]))[0]
        paper = 150.0 * p2 / (p2 + 1.0)
        rows.append((p2, closed, series, paper))
        assert abs(closed - paper) < 1e-9
        # the Eq.-(3) geometric series must agree with both closed forms
        # (truncated at machine precision, hence the looser tolerance)
        assert abs(series - paper) < 1e-6, (p2, series, paper)
    if csv:
        print("fig2_bias,p2,E_x_fedavg,E_x_series,paper_formula")
        for p2, c, s, f in rows:
            print(f"fig2_bias,{p2:.3f},{c:.4f},{s:.4f},{f:.4f}")
    return rows


if __name__ == "__main__":
    run()
