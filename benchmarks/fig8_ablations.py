"""Fig. 8 ablations: impact of alpha (data heterogeneity), gamma (p_i^t
fluctuation), delta (p_i floor), sigma0 (class-weight spread) on FedPBC and
FedAvg under Bernoulli time-varying links.

Each swept parameter is ONE ``SweepSpec`` whose hyperparameter axis carries
all the values: every (value x seed) trajectory of an ablation executes as
one compiled program per algorithm. All four knobs are traced inputs on the
batched sweep core — gamma through the link factory's traced scalar,
delta/sigma0 through the traced per-trajectory ``p_base``, alpha through both
``p_base`` and the traced partition table — so the figure is served by ONE
cached runner per algorithm: no swept *value* ever compiles. Only the two
distinct flattened batch *shapes* (the 2-value and 3-value ablations) add an
executable per jitted stage, where the per-value path used to pay a fresh
task and/or compile per alpha and gamma value."""
from __future__ import annotations

import dataclasses

from repro.experiments import SweepSpec, run_sweep

SWEEPS = {
    "alpha": [0.1, 1.0],
    "gamma": [0.1, 0.5, 0.9],
    "delta": [0.001, 0.02, 0.1],
    "sigma0": [1.0, 10.0],
}

def run(csv=True, *, rounds=200, m=100, algos=("fedpbc", "fedavg"), seed=0,
        store=None):
    if csv:
        print("fig8,param,value,algo,test_acc")
    base = SweepSpec(algorithms=tuple(algos), schemes=("bernoulli_tv",),
                     seeds=(seed,), rounds=rounds,
                     eval_every=min(25, rounds), num_clients=m)
    out = {}
    for param, values in SWEEPS.items():
        # the axis field for a scalar knob is its plural (SweepSpec naming)
        spec = dataclasses.replace(base, **{param + "s": tuple(values)})
        for cell in run_sweep(spec, store=store, suite=f"fig8_{param}"):
            v = cell.hparams[param]
            acc = float(cell.final_test().mean())
            out[(param, v, cell.algo)] = acc
            if csv:
                print(f"fig8,{param},{v},{cell.algo},{acc:.4f}",
                      flush=True)
    return out


if __name__ == "__main__":
    run()
