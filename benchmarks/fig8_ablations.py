"""Fig. 8 ablations: impact of alpha (data heterogeneity), gamma (p_i^t
fluctuation), delta (p_i floor), sigma0 (class-weight spread) on FedPBC and
FedAvg under Bernoulli time-varying links.

Each swept value is one ``SweepSpec`` on the vectorized engine. delta/sigma0
enter the compiled program only through the traced per-seed ``p_base``
inputs, so those ablation rows reuse ONE compiled runner per algorithm
(the grid executor's compile cache); alpha re-partitions the dataset and
gamma is baked into the link closures, so those recompile."""
from __future__ import annotations

import dataclasses

from repro.experiments import SweepSpec, run_sweep

SWEEPS = {
    "alpha": [0.1, 1.0],
    "gamma": [0.1, 0.5, 0.9],
    "delta": [0.001, 0.02, 0.1],
    "sigma0": [1.0, 10.0],
}


def run(csv=True, *, rounds=200, m=100, algos=("fedpbc", "fedavg"), seed=0,
        store=None):
    if csv:
        print("fig8,param,value,algo,test_acc")
    base = SweepSpec(algorithms=tuple(algos), schemes=("bernoulli_tv",),
                     seeds=(seed,), rounds=rounds,
                     eval_every=min(25, rounds), num_clients=m)
    out = {}
    for param, values in SWEEPS.items():
        for v in values:
            spec = dataclasses.replace(base, **{param: v})
            for cell in run_sweep(spec, store=store,
                                  suite=f"fig8_{param}"):
                acc = float(cell.final_test().mean())
                out[(param, v, cell.algo)] = acc
                if csv:
                    print(f"fig8,{param},{v},{cell.algo},{acc:.4f}",
                          flush=True)
    return out


if __name__ == "__main__":
    run()
