"""Fig. 8 ablations: impact of alpha (data heterogeneity), gamma (p_i^t
fluctuation), delta (p_i floor), sigma0 (class-weight spread) on FedPBC and
FedAvg under Bernoulli time-varying links."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_training


SWEEPS = {
    "alpha": [0.1, 1.0],
    "gamma": [0.1, 0.5, 0.9],
    "delta": [0.001, 0.02, 0.1],
    "sigma0": [1.0, 10.0],
}


def run(csv=True, *, rounds=200, m=100, algos=("fedpbc", "fedavg"), seed=0):
    if csv:
        print("fig8,param,value,algo,test_acc")
    out = {}
    for param, values in SWEEPS.items():
        for v in values:
            kw = {param: v} if param != "gamma" else {"gamma": v}
            for algo in algos:
                traj, _ = run_training(algo, "bernoulli_tv", rounds=rounds,
                                       m=m, seed=seed, **kw)
                acc = np.mean([a for _, a in traj[-3:]])
                out[(param, v, algo)] = float(acc)
                if csv:
                    print(f"fig8,{param},{v},{algo},{acc:.4f}", flush=True)
    return out


if __name__ == "__main__":
    run()
