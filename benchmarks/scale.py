"""Cross-device scale: cohort-subsampled buffered aggregation vs m.

The acceptance workload of the ``repro.scale`` subsystem: a FedPBC cell at
m in {1k, 10k, 50k} clients with a C=256 on-device cohort per round and a
(sync, buffered) strategy pair — ONE compiled program per cell (the
strategy knobs are traced per-trajectory columns; the compile counter
asserts it), O(C) per-round client-tensor memory (no ``[m, n_params]``
intermediate exists anywhere in the cohort round — ``FedState.clients``
is ``()``).

Per m the bench reports cold (includes the compile) and warm wall time,
rounds/sec, the buffered arm's commit count and mean per-commit staleness,
and both arms' final test accuracy. The figure of merit is warm
rounds/sec vs m: the cohort round's client compute is O(C), so the cost
should grow far sublinearly in m (the residual O(m) terms are the link
process and the per-client bookkeeping vectors).

Prints a ``BENCH {...}`` JSON line and writes ``benchmarks/out/scale.json``.

  PYTHONPATH=src python -m benchmarks.scale             # full m ladder
  PYTHONPATH=src python -m benchmarks.scale --smoke     # m=10k, few rounds
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.experiments import SweepSpec, run_cell_batch
from repro.experiments.grid import _runner_for, get_traced_task
from repro.scale import BUFFER_METRIC_KEYS, Strategy

METRIC_KEYS = ("loss", "num_active") + BUFFER_METRIC_KEYS
SCHEME = "bernoulli_ti"


def _spec(m: int, *, cohort: int, rounds: int, seeds) -> SweepSpec:
    buffered = Strategy("buffered", buffer_size=max(cohort // 2, 1),
                        deadline_rounds=4)
    return SweepSpec(
        algorithms=("fedpbc",), schemes=(SCHEME,), seeds=tuple(seeds),
        rounds=rounds, eval_every=rounds,        # one in-scan eval at the end
        num_clients=m, cohort_size=min(cohort, m),
        strategies=(Strategy("sync_cohort"), buffered),
        local_steps=2, batch_size=16, dim=32, hidden=32,
        n_per_class=200, n_train=1600, per_client=32)


def _bench_m(m: int, *, cohort: int, rounds: int, seeds) -> dict:
    spec = _spec(m, cohort=cohort, rounds=rounds, seeds=seeds)
    C = spec.cohort_size

    t0 = time.perf_counter()
    cells = run_cell_batch(spec, "fedpbc", SCHEME, metric_keys=METRIC_KEYS,
                           mesh=None)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cells = run_cell_batch(spec, "fedpbc", SCHEME, metric_keys=METRIC_KEYS,
                           mesh=None)
    warm_s = time.perf_counter() - t0

    fed = spec.cell_config("fedpbc", SCHEME)
    runner = _runner_for(spec, fed, get_traced_task(spec), METRIC_KEYS)
    compiles = -1
    if hasattr(runner.scan_batch, "_cache_size"):
        compiles = runner.init_batch._cache_size() \
            + runner.scan_batch._cache_size()
        # both strategies share ONE (init, scan) pair — the subsystem's
        # compile contract. RuntimeError (not assert): survives `python -O`
        if compiles != 2:
            raise RuntimeError(
                f"strategy axis recompiled: {compiles} jit entries, "
                "expected 2 (one init + one scan for the whole cell)")

    sync_c, buf_c = cells
    commits = np.asarray(buf_c.commit)
    stale = np.asarray(buf_c.commit_staleness)
    n_commits = commits.sum(axis=1)
    mean_stale = float(
        ((stale * commits).sum(axis=1) / np.maximum(n_commits, 1.0)).mean())
    n_traj = len(spec.seeds) * len(spec.strategies)
    return {
        "m": m,
        "cohort": C,
        "rounds": rounds,
        "n_seeds": len(spec.seeds),
        "strategies": [s.name for s in spec.strategies],
        "buffer_size": spec.strategies[1].buffer_size,
        "deadline_rounds": spec.strategies[1].deadline_rounds,
        "cold_seconds": round(cold_s, 4),
        "warm_seconds": round(warm_s, 4),
        "warm_rounds_per_s": round(n_traj * rounds / warm_s, 2),
        "compile_entries": compiles,
        "commits_per_seed": [float(x) for x in n_commits],
        "mean_commit_staleness": round(mean_stale, 4),
        "final_test_acc_sync": round(float(sync_c.test_acc[:, -1].mean()), 4),
        "final_test_acc_buffered":
            round(float(buf_c.test_acc[:, -1].mean()), 4),
    }


def run(csv=True, *, ms=(1_000, 10_000, 50_000), cohort=256, rounds=30,
        seeds=(0,), out_path=None):
    entries = []
    for m in ms:
        e = _bench_m(m, cohort=cohort, rounds=rounds, seeds=seeds)
        if csv:
            print(f"scale,m={m},C={e['cohort']},warm_s={e['warm_seconds']},"
                  f"rps={e['warm_rounds_per_s']},"
                  f"acc_buf={e['final_test_acc_buffered']}", flush=True)
        entries.append(e)
    result = {
        "bench": "scale",
        "cohort": cohort,
        "rounds": rounds,
        "by_m": {f"scale_m{e['m']}": e for e in entries},
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "out",
                                "scale.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=30)
    ap.add_argument("--cohort", type=int, default=256)
    ap.add_argument("--ms", default="1000,10000,50000",
                    help="comma-separated client counts")
    ap.add_argument("--smoke", action="store_true",
                    help="one fast arm (m=10000, 6 rounds) for CI")
    a = ap.parse_args()
    if a.smoke:
        run(ms=(10_000,), cohort=a.cohort, rounds=6)
    else:
        run(ms=tuple(int(x) for x in a.ms.split(",")), cohort=a.cohort,
            rounds=a.rounds)
