"""Roofline table: reads the dry-run artifact (experiments/dryrun_all.json)
and prints the per-(arch x shape x mesh) roofline terms."""
from __future__ import annotations

import json
import os

DEFAULT = "experiments/dryrun_all.json"


def run(csv=True, path=DEFAULT):
    if not os.path.exists(path):
        print(f"# roofline: {path} not found — run "
              "`python -m repro.launch.dryrun --all --both-meshes --out "
              f"{path}` first")
        return []
    rows = json.load(open(path))
    if csv:
        print("roofline,arch,shape,mesh,status,t_compute_s,t_memory_s,"
              "t_collective_s,bottleneck,useful_fraction,temp_GB_per_dev")
    for r in rows:
        if r["status"] != "ok":
            print(f"roofline,{r['arch']},{r['shape']},{r.get('mesh','')},"
                  f"{r['status']},,,,,,")
            continue
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},ok,"
              f"{r['t_compute_s']:.4f},{r['t_memory_s']:.4f},"
              f"{r['t_collective_s']:.4f},{r['bottleneck']},"
              f"{r['useful_fraction']:.3f},"
              f"{(r.get('temp_bytes_per_device') or 0)/1e9:.1f}")
    return rows


if __name__ == "__main__":
    run()
