"""Shared harness for the paper-replication benchmarks.

The grid definitions (ALGOS, SCHEMES) and the synthetic stand-in task (2-layer
MLP on the 10-class Gaussian dataset; see ``repro.experiments.tasks`` for why)
live in ``repro.experiments`` — benchmarks re-export them. The table/figure
suites themselves run on the vectorized sweep engine
(``repro.experiments.grid.run_sweep``): S seeds of one (algo, scheme) cell
execute as ONE compiled program.

``run_training`` — one cell-seed per Python call, fresh closures (and hence a
fresh compile) every time, per-seed dataset — is kept as the simplest entry
point for one-off runs (``benchmarks/extensions.py``).
``benchmarks/sweep_throughput.py`` builds its sequential baseline on the
engine's own shared-dataset protocol instead, so its accuracy columns are
comparable across arms and trajectory equality is asserted in the bench.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import (
    build_base_probs,
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_run_rounds,
)
from repro.data import (
    classification_source,
    dirichlet_partition,
    make_classification_data,
)
from repro.experiments.grid import ALGOS, SCHEMES  # noqa: F401  (re-export)
from repro.experiments.tasks import (  # noqa: F401  (re-export)
    mlp_accuracy,
    mlp_init,
    mlp_loss,
)
from repro.optim import paper_decay, sgd


def accuracy(params, x, y):
    return float(mlp_accuracy(params, x, y))


def run_training(algo_name, scheme_key, *, rounds=300, m=100, seed=0,
                 alpha=0.1, sigma0=10.0, delta=0.02, gamma=0.5,
                 eval_every=25):
    """One federated run; returns (test-acc trajectory, train-acc final)."""
    skw = dict(SCHEMES[scheme_key])
    rng = np.random.default_rng(seed)
    x_all, y_all = make_classification_data(seed, dim=32, n_per_class=600, sep=3.0)
    n_train = 5000
    x, y = x_all[:n_train], y_all[:n_train]
    xt, yt = x_all[n_train:], y_all[n_train:]
    idx, _ = dirichlet_partition(rng, y, m, alpha=alpha, per_client=64)
    fed = FederationConfig(algorithm=algo_name, num_clients=m, local_steps=5,
                           gamma=gamma, delta=delta, sigma0=sigma0,
                           alpha=alpha, **skw)
    p, _, _ = build_base_probs(jax.random.PRNGKey(seed), m, 10, alpha=alpha,
                               sigma0=sigma0, delta=delta)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    opt = sgd(paper_decay(0.1))
    source = classification_source(x, y, idx, local_steps=5, batch_size=32)
    run_rounds = make_run_rounds(mlp_loss, opt, algo, link, fed, source)
    params = mlp_init(jax.random.PRNGKey(seed + 1))
    st = init_fed_state(jax.random.PRNGKey(seed + 2), params, fed, algo, link, opt)
    ds_state = source.init(jax.random.PRNGKey(seed + 3))
    data_key = jax.random.PRNGKey(seed + 4)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    x_j, y_j = jnp.asarray(x), jnp.asarray(y)
    traj = []
    t = 0
    while t < rounds:
        chunk = min(eval_every, rounds - t)
        st, ds_state, _ = run_rounds(st, ds_state, data_key, chunk)
        t += chunk
        traj.append((t, accuracy(st.server, xt_j, yt_j)))
    train_acc = accuracy(st.server, x_j, y_j)
    return traj, train_acc


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
