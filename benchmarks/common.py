"""Shared harness for the paper-replication benchmarks.

The image datasets of the paper (SVHN/CIFAR-10/CINIC-10) are not available
offline; the benchmarks run the same protocol (m=100 clients, Dirichlet(0.1)
non-IID split, Eq.-9 heterogeneous p_i, 5 local steps, decaying LR) on the
synthetic 10-class Gaussian task from ``repro.data.synthetic`` with a 2-layer
MLP. Scale knobs (--rounds, --clients) trade fidelity for CPU time.

Training runs on the scanned multi-round engine: the dataset and the
per-client index table live on device (``repro.data.classification_source``)
and ``eval_every`` rounds execute as ONE ``run_rounds`` dispatch, so the
scheme x algorithm sweeps are no longer bounded by per-round Python dispatch.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import (
    build_base_probs,
    init_fed_state,
    make_algorithm,
    make_link_process,
    make_run_rounds,
)
from repro.data import (
    classification_source,
    dirichlet_partition,
    make_classification_data,
)
from repro.optim import paper_decay, sgd

ALGOS = ["fedpbc", "fedavg", "fedavg_all", "fedau", "f3ast",
         "fedavg_known_p", "mifa"]

SCHEMES = {
    "bernoulli_ti": dict(scheme="bernoulli", time_varying=False),
    "bernoulli_tv": dict(scheme="bernoulli", time_varying=True),
    "markov_hom": dict(scheme="markov", time_varying=False),
    "markov_nonhom": dict(scheme="markov", time_varying=True),
    "cyclic": dict(scheme="cyclic", cyclic_reset=False),
    "cyclic_reset": dict(scheme="cyclic", cyclic_reset=True),
}


def mlp_init(key, dim=32, classes=10, hidden=64):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
        "b1": jnp.zeros(hidden),
        "w2": jax.random.normal(k2, (hidden, classes)) * hidden ** -0.5,
        "b2": jnp.zeros(classes),
    }


def mlp_loss(params, batch):
    h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    labels = jax.nn.one_hot(batch["y"], logits.shape[-1])
    return -jnp.mean(jnp.sum(labels * jax.nn.log_softmax(logits), -1))


def accuracy(params, x, y):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    logits = h @ params["w2"] + params["b2"]
    return float((jnp.argmax(logits, -1) == y).mean())


def run_training(algo_name, scheme_key, *, rounds=300, m=100, seed=0,
                 alpha=0.1, sigma0=10.0, delta=0.02, gamma=0.5,
                 eval_every=25):
    """One federated run; returns (test-acc trajectory, train-acc final)."""
    skw = dict(SCHEMES[scheme_key])
    rng = np.random.default_rng(seed)
    x_all, y_all = make_classification_data(seed, dim=32, n_per_class=600, sep=3.0)
    n_train = 5000
    x, y = x_all[:n_train], y_all[:n_train]
    xt, yt = x_all[n_train:], y_all[n_train:]
    idx, _ = dirichlet_partition(rng, y, m, alpha=alpha, per_client=64)
    fed = FederationConfig(algorithm=algo_name, num_clients=m, local_steps=5,
                           gamma=gamma, delta=delta, sigma0=sigma0,
                           alpha=alpha, **skw)
    p, _, _ = build_base_probs(jax.random.PRNGKey(seed), m, 10, alpha=alpha,
                               sigma0=sigma0, delta=delta)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    opt = sgd(paper_decay(0.1))
    source = classification_source(x, y, idx, local_steps=5, batch_size=32)
    run_rounds = make_run_rounds(mlp_loss, opt, algo, link, fed, source)
    params = mlp_init(jax.random.PRNGKey(seed + 1))
    st = init_fed_state(jax.random.PRNGKey(seed + 2), params, fed, algo, link, opt)
    ds_state = source.init(jax.random.PRNGKey(seed + 3))
    data_key = jax.random.PRNGKey(seed + 4)
    xt_j, yt_j = jnp.asarray(xt), jnp.asarray(yt)
    x_j, y_j = jnp.asarray(x), jnp.asarray(y)
    traj = []
    t = 0
    while t < rounds:
        chunk = min(eval_every, rounds - t)
        st, ds_state, _ = run_rounds(st, ds_state, data_key, chunk)
        t += chunk
        traj.append((t, accuracy(st.server, xt_j, yt_j)))
    train_acc = accuracy(st.server, x_j, y_j)
    return traj, train_acc


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0
