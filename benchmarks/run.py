"""Benchmark driver — one module per paper table/figure. Prints CSV.

  python -m benchmarks.run                    # default (CPU-budget) suite
  python -m benchmarks.run --list             # what can run, then exit
  python -m benchmarks.run --only fig3
  python -m benchmarks.run --only fig2,table1,sweep   # comma-separated list
  python -m benchmarks.run --rounds 400       # longer federated runs
"""
from __future__ import annotations

import argparse
import time

# suite name -> (one-line description, arms within the suite's BENCH output).
# --list prints this table so nobody greps the source for --only values.
SUITE_INFO = {
    "fig2": ("Eq.-3 FedAvg bias series vs simulation", ()),
    "fig3": ("quadratic counterexample convergence curves", ()),
    "table1": ("final test accuracy grid (algorithms x schemes)", ()),
    "table2": ("rounds-to-target-accuracy grid (writes the machine-readable "
               "baseline JSON benchmarks/asha.py consumes)", ()),
    "fig8": ("alpha/gamma/delta/sigma0 ablations on one traced axis", ()),
    "extensions": ("beyond-paper extensions (fedpbc_m momentum)", ()),
    "throughput": ("scanned round engine vs per-round dispatch", ()),
    "sweep": ("batched sweep engine vs sequential/per-value baselines",
              ("seed_axis", "hparam_ablation", "algo_axis",
               "device_scaling")),
    "roofline": ("arithmetic-intensity roofline of the model zoo", ()),
    "kernels": ("pallas kernels vs reference ops (fused batched aggregation "
                "+ TPU-target oracles)",
                ("batched_agg_B8_m32_n1024", "batched_agg_B8_m256_n1024",
                 "batched_agg_B64_m32_n1024", "batched_agg_B64_m256_n1024")),
    "scale": ("cross-device cohort + buffered aggregation vs client count",
              ("scale_m1000", "scale_m10000", "scale_m50000")),
    "lm_sweep": ("federated LM family sweep on the 2-D (batch, model) mesh "
                 "vs one device, roofline-gated",
                 ("lm_family", "cohort")),
    "asha": ("successive-halving search vs exhaustive grid (time-to-target "
             "on the resumable segment runner)", ("asha_vs_grid",)),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of "
                         f"{'|'.join(SUITE_INFO)} (e.g. --only fig2,table1)")
    ap.add_argument("--list", action="store_true",
                    help="print available suites (and their BENCH arms) and "
                         "exit")
    ap.add_argument("--rounds", type=int, default=250)
    args = ap.parse_args()

    if args.list:
        for name, (desc, arms) in SUITE_INFO.items():
            line = f"{name:12s} {desc}"
            if arms:
                line += f"  [arms: {', '.join(arms)}]"
            print(line)
        return

    from benchmarks import (
        asha,
        extensions,
        fig2_bias,
        fig3_quadratic,
        fig8_ablations,
        kernels_bench,
        lm_sweep,
        roofline,
        scale,
        sweep_throughput,
        table1_accuracy,
        table2_rounds_to_target,
        throughput,
    )

    suites = {
        "fig2": lambda: fig2_bias.run(),
        "fig3": lambda: fig3_quadratic.run(rounds=min(args.rounds * 2, 800)),
        "table1": lambda: table1_accuracy.run(rounds=args.rounds),
        "table2": lambda: table2_rounds_to_target.run(rounds=args.rounds),
        "fig8": lambda: fig8_ablations.run(rounds=max(args.rounds // 2, 100)),
        "extensions": lambda: extensions.run(rounds=args.rounds),
        "throughput": lambda: throughput.run(rounds=max(args.rounds, 200)),
        "sweep": lambda: sweep_throughput.run(rounds=max(args.rounds // 2, 100)),
        "roofline": lambda: roofline.run(),
        "kernels": lambda: kernels_bench.run(),
        "scale": lambda: scale.run(rounds=max(args.rounds // 8, 20)),
        "lm_sweep": lambda: lm_sweep.run(rounds=max(args.rounds // 25, 4)),
        "asha": lambda: asha.run(rounds=max(args.rounds // 4, 32)),
    }
    assert set(suites) == set(SUITE_INFO)
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in suites]
        if unknown:
            ap.error(f"unknown suite(s) {','.join(unknown)}; "
                     f"available: {','.join(suites)}")
    else:
        names = list(suites)
    for name in names:
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        suites[name]()
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
