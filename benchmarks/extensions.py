"""Beyond-paper extension: FedPBC-M (server momentum on the aggregated
direction) vs FedPBC under sparse, heterogeneous participation."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_training


def run(csv=True, *, rounds=250, m=100, seeds=(0,)):
    if csv:
        print("extensions,scheme,algo,test_acc_mean")
    out = {}
    for scheme in ("bernoulli_tv", "markov_nonhom"):
        for algo in ("fedpbc", "fedpbc_m"):
            accs = []
            for sd in seeds:
                traj, _ = run_training(algo, scheme, rounds=rounds, m=m, seed=sd)
                accs.append(np.mean([a for _, a in traj[-3:]]))
            out[(scheme, algo)] = float(np.mean(accs))
            if csv:
                print(f"extensions,{scheme},{algo},{np.mean(accs):.4f}", flush=True)
    return out


if __name__ == "__main__":
    run()
