"""Table 1: final train/test accuracy of all 7 algorithms under the six
unreliable-uplink schemes (synthetic stand-in dataset; see common.py).

Runs on the batched sweep core: all trajectories of one (scheme, algo) cell
— here a single hyperparameter point x all seeds — execute as ONE compiled
program with the dataset, partition, lr, and Eq.-9 knobs as traced inputs,
so re-running the table at a different lr/alpha reuses every compile.
Results append to the JSONL/npz store under ``benchmarks/out/sweeps`` with
their hyperparameter coordinates recorded (CSV stays as the console view).

Default: 2 schemes x 7 algos x 1 seed at 250 rounds (CPU budget);
--full runs all 6 schemes x 3 seeds."""
from __future__ import annotations

import dataclasses
import os

from repro.experiments import ResultsStore, SweepSpec, run_sweep

from benchmarks.common import ALGOS, SCHEMES


def _default_store():
    return ResultsStore(os.path.join(os.path.dirname(__file__), "out", "sweeps"))


def run(csv=True, *, schemes=("bernoulli_ti", "bernoulli_tv"),
        algos=ALGOS, rounds=250, m=100, seeds=(0,), store=None):
    if store is None:
        store = _default_store()
    spec = SweepSpec(algorithms=tuple(algos), schemes=tuple(schemes),
                     seeds=tuple(seeds), rounds=rounds,
                     eval_every=min(25, rounds), num_clients=m)
    if csv:
        print("table1,scheme,algo,test_acc_mean,test_acc_std,train_acc")
    results = {}
    for cell in run_sweep(spec, store=store, suite="table1"):
        # same summarize() reduction the store records (ddof=1 std), so the
        # CSV view and the JSONL summary agree
        s = cell.summary()
        mean, std = s["test_acc"]["mean"], s["test_acc"]["std"]
        results[(cell.scheme, cell.algo)] = (mean, std)
        if csv:
            print(f"table1,{cell.scheme},{cell.algo},{mean:.4f},"
                  f"{std:.4f},{s['train_acc']['mean']:.4f}", flush=True)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=250)
    a = ap.parse_args()
    if a.full:
        run(schemes=tuple(SCHEMES), rounds=max(a.rounds, 400), seeds=(0, 1, 2))
    else:
        run(rounds=a.rounds)
