"""Table 1: final train/test accuracy of all 7 algorithms under the six
unreliable-uplink schemes (synthetic stand-in dataset; see common.py).

Default: 2 schemes x 7 algos x 1 seed at 250 rounds (CPU budget);
--full runs all 6 schemes x 3 seeds."""
from __future__ import annotations

import numpy as np

from benchmarks.common import ALGOS, SCHEMES, run_training


def run(csv=True, *, schemes=("bernoulli_ti", "bernoulli_tv"),
        algos=ALGOS, rounds=250, m=100, seeds=(0,)):
    if csv:
        print("table1,scheme,algo,test_acc_mean,test_acc_std,train_acc")
    results = {}
    for scheme in schemes:
        for algo in algos:
            accs, tr = [], []
            for sd in seeds:
                traj, train_acc = run_training(algo, scheme, rounds=rounds,
                                               m=m, seed=sd)
                accs.append(np.mean([a for _, a in traj[-3:]]))
                tr.append(train_acc)
            results[(scheme, algo)] = (float(np.mean(accs)), float(np.std(accs)))
            if csv:
                print(f"table1,{scheme},{algo},{np.mean(accs):.4f},"
                      f"{np.std(accs):.4f},{np.mean(tr):.4f}", flush=True)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--rounds", type=int, default=250)
    a = ap.parse_args()
    if a.full:
        run(schemes=tuple(SCHEMES), rounds=max(a.rounds, 400), seeds=(0, 1, 2))
    else:
        run(rounds=a.rounds)
