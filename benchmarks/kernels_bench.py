"""Kernel micro-benchmarks.

Historical arms time the pure-jnp references on CPU (the flash-attention /
rwkv kernels are TPU-target; interpret-mode timing is not meaningful there)
and report kernel/oracle allclose deltas.

The ``batched_agg`` arm times the sweep hot path both ways: the fused
family-aggregation kernel through ``repro.kernels.dispatch`` (the backend
the current platform resolves to) against the pure-XLA reference, at the
sweep layout ``[B, m, n]`` with mixed per-trajectory opcodes. Emits a
``BENCH {...}`` JSON line and writes ``benchmarks/out/kernels.json`` with
per-arm ``xla_us`` / ``kernel_us`` / ``speedup`` / ``max_abs_diff``. On CPU
the kernel runs in interpret mode (same XLA ops, so speedup ~1 is expected
and the interesting column is ``max_abs_diff == 0``); on TPU/GPU the
compiled kernel is the one being sold.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (
    OP_ALL,
    OP_KNOWN_P,
    OP_MEAN,
    flash_attention,
    flash_attention_ref,
    fused_agg,
    masked_agg,
    masked_agg_ref,
    resolve_backend,
    rwkv6_chunk,
    rwkv6_chunk_ref,
)


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def batched_agg_arms(key, sizes=((8, 32), (8, 256), (64, 32), (64, 256)),
                     n=1024, reps=5):
    """Time fused kernel (resolved backend) vs the XLA reference per
    ``[B, m, n]`` size; returns the BENCH sub-dict list."""
    backend = resolve_backend()
    call = jax.jit(fused_agg, static_argnames=("backend", "block_n"))
    arms = []
    for B, m in sizes:
        k = jax.random.fold_in(key, B * m)
        x = jax.random.normal(k, (B, m, n))
        mask = jax.random.uniform(jax.random.fold_in(k, 1), (B, m)) < 0.5
        prev = jax.random.normal(jax.random.fold_in(k, 2), (B, n))
        p = jax.random.uniform(jax.random.fold_in(k, 3), (B, m),
                               minval=0.05, maxval=1.0)
        ops = jnp.asarray([(OP_MEAN, OP_ALL, OP_KNOWN_P)[b % 3]
                           for b in range(B)], jnp.int32)
        args = (x, mask, ops, prev, p)
        kernel_us = _time(lambda *a: call(*a, backend=backend), *args,
                          reps=reps)
        xla_us = _time(lambda *a: call(*a, backend="xla"), *args, reps=reps)
        diff = float(jnp.max(jnp.abs(call(*args, backend=backend)
                                     - call(*args, backend="xla"))))
        arms.append({
            "arm": f"batched_agg_B{B}_m{m}_n{n}",
            "B": B, "m": m, "n": n,
            "kernel_backend": backend,
            "kernel_us": round(kernel_us, 1),
            "xla_us": round(xla_us, 1),
            "speedup": round(xla_us / kernel_us, 3),
            "max_abs_diff": diff,
        })
    return arms


def run(csv=True, out_path=None):
    key = jax.random.PRNGKey(0)
    rows = []

    agg_arms = batched_agg_arms(jax.random.fold_in(key, 100))
    for a in agg_arms:
        rows.append((a["arm"], a["kernel_us"],
                     f"xla_us={a['xla_us']};speedup={a['speedup']};"
                     f"max_abs_diff={a['max_abs_diff']:.2e}"))

    x = jax.random.normal(key, (64, 1 << 16))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (64,)) < 0.5)
    us = _time(jax.jit(masked_agg_ref), x, mask)
    err = float(jnp.max(jnp.abs(masked_agg(x, mask) - masked_agg_ref(x, mask))))
    rows.append(("masked_agg_64x65536", us, f"kernel_max_err={err:.2e}"))

    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (1, 4, 512, 64))
               for i in range(3))
    us = _time(jax.jit(flash_attention_ref), q, k, v)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v)
                                - flash_attention_ref(q, k, v))))
    rows.append(("flash_attention_512", us, f"kernel_max_err={err:.2e}"))

    b, h, t, d = 1, 4, 256, 64
    r_, k_, v_ = (0.5 * jax.random.normal(jax.random.fold_in(key, 10 + i),
                                          (b, h, t, d)) for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 13), (b, h, t, d))))
    u = 0.2 * jax.random.normal(jax.random.fold_in(key, 14), (h, d))
    s0 = jnp.zeros((b, h, d, d))
    us = _time(jax.jit(rwkv6_chunk_ref), r_, k_, v_, w, u, s0)
    o1, _ = rwkv6_chunk(r_, k_, v_, w, u, s0)
    o2, _ = rwkv6_chunk_ref(r_, k_, v_, w, u, s0)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    rows.append(("rwkv6_chunk_256", us, f"kernel_max_err={err:.2e}"))

    result = {
        "suite": "kernels",
        "backend": jax.default_backend(),
        "kernel_backend": resolve_backend(),
        "batched_agg": agg_arms,
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "out",
                                "kernels.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    if csv:
        print("kernels,name,us_per_call,derived")
        for n, us, d_ in rows:
            print(f"kernels,{n},{us:.1f},{d_}")
    return rows


if __name__ == "__main__":
    run()
