"""Kernel micro-benchmarks: pure-jnp reference timings on CPU (the Pallas
kernels are TPU-target; interpret-mode timing is not meaningful, so we time
the jnp oracles and report kernel/oracle allclose deltas)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (
    flash_attention,
    flash_attention_ref,
    masked_agg,
    masked_agg_ref,
    rwkv6_chunk,
    rwkv6_chunk_ref,
)


def _time(fn, *args, reps=5):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv=True):
    key = jax.random.PRNGKey(0)
    rows = []

    x = jax.random.normal(key, (64, 1 << 16))
    mask = (jax.random.uniform(jax.random.fold_in(key, 1), (64,)) < 0.5)
    us = _time(jax.jit(masked_agg_ref), x, mask)
    err = float(jnp.max(jnp.abs(masked_agg(x, mask) - masked_agg_ref(x, mask))))
    rows.append(("masked_agg_64x65536", us, f"kernel_max_err={err:.2e}"))

    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (1, 4, 512, 64))
               for i in range(3))
    us = _time(jax.jit(flash_attention_ref), q, k, v)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v)
                                - flash_attention_ref(q, k, v))))
    rows.append(("flash_attention_512", us, f"kernel_max_err={err:.2e}"))

    b, h, t, d = 1, 4, 256, 64
    r_, k_, v_ = (0.5 * jax.random.normal(jax.random.fold_in(key, 10 + i),
                                          (b, h, t, d)) for i in range(3))
    w = jnp.exp(-jnp.exp(-3.0 + 0.3 * jax.random.normal(
        jax.random.fold_in(key, 13), (b, h, t, d))))
    u = 0.2 * jax.random.normal(jax.random.fold_in(key, 14), (h, d))
    s0 = jnp.zeros((b, h, d, d))
    us = _time(jax.jit(rwkv6_chunk_ref), r_, k_, v_, w, u, s0)
    o1, _ = rwkv6_chunk(r_, k_, v_, w, u, s0)
    o2, _ = rwkv6_chunk_ref(r_, k_, v_, w, u, s0)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    rows.append(("rwkv6_chunk_256", us, f"kernel_max_err={err:.2e}"))

    if csv:
        print("kernels,name,us_per_call,derived")
        for n, us, d_ in rows:
            print(f"kernels,{n},{us:.1f},{d_}")
    return rows


if __name__ == "__main__":
    run()
