"""Sweep-throughput: the batched engine vs its sequential / per-value-recompile
baselines, on two axes.

1. **Seed axis** (the PR-2 acceptance workload): one (fedpbc, bernoulli_ti)
   cell at m=32 clients over S=8 seeds.

   - ``sequential``: S per-seed runs with fresh closures each (data source,
     link, round step), so every seed pays its own XLA compile on top of its
     own scan dispatch — the pre-subsystem execution model. Both arms now run
     the SAME protocol (shared ``data_seed=0`` dataset and partition, engine
     key bundles, per-seed Eq.-9 ``p_base``), so the accuracy columns are
     directly comparable and the bench ASSERTS trajectory agreement between
     the arms (``trajectory_max_abs_diff``) instead of printing two
     incomparable numbers.
   - ``vmapped``: ``repro.experiments.grid.run_cell`` — all S seeds as ONE
     compiled program. Reported cold (includes the compile) and warm.

2. **Hyperparameter axis** (the PR-3 acceptance workload): an
   lr x alpha ablation grid x S seeds of the same cell.

   - ``per-value-recompile``: one PR-2-style seed-axis runner per point with
     the lr baked into its optimizer closure (a fresh compile pair per point)
     and the task rebuilt per distinct alpha (the dataset partition was a jit
     constant) — the pre-refactor cost model.
   - ``traced``: ``run_cell_batch`` — every (lr, alpha, seed) trajectory in
     ONE compiled program, lr as a traced scalar and the alpha partition as a
     traced index table. Compile counts for both arms come from the runners'
     jit cache sizes.

3. **Algorithm axis** (the AlgorithmSpec-refactor acceptance workload): the
   state-compatible fedpbc/fedavg/fedavg_all/fedavg_known_p family — the
   paper's FedPBC-vs-baselines comparison — run two ways:

   - ``per-algorithm``: one statically-dispatched runner per algorithm (a
     fresh (init, scan) compile pair each, 4 programs total) — the
     pre-refactor cost model;
   - ``batched``: ONE switch-based family program over the joint
     (algo x point x seed) batch axis, the traced ``algo_id`` selecting each
     trajectory's rule. Compile counts come from the runners' jit caches
     (``algo_axis.batched_compile_programs`` must be 1 vs one per algorithm
     for the baseline), and the arms' trajectories are asserted to agree.

4. **Device axis**: the SAME batched cell program executed single-device vs
   sharded over a ``("batch",)`` mesh
   of every visible device (``repro.experiments.shard``), warm timings both
   ways plus the max per-trajectory deviation (must be 0.0 — sharding the
   batch axis is a placement change, not a numeric one). Runnable on CPU via
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — note forced host
   devices SHARE the physical cores, so the sharded cells/sec measures
   partitioning overhead there, not real scaling; on real multi-device
   backends it measures scaling. With a single visible device the entry
   records ``n_devices: 1`` and the rerun recipe. The seed/hparam arms above
   pin ``mesh=None`` so their numbers stay comparable across environments.

The hyperparameter comparison is steady-state: a per-value-recompile path
recompiles for EVERY new swept value, forever, while the traced path's one
compile serves any values of the same grid shape — so after the first (cold,
also reported) ablation, the bench re-runs the traced arm with *entirely
different* lr/alpha values and verifies via the jit caches that it compiled
nothing; that run vs the baseline's unavoidable recompile cost is the
headline ``hparam_ablation.speedup``.

The figure of merit is cells/sec where one "cell" = one trajectory of
``rounds`` rounds. Prints a ``BENCH {...}`` JSON line and writes
``benchmarks/out/sweep_throughput.json``. Acceptance bars: ``speedup >= 2``
(warm vmapped vs sequential, seed axis), ``hparam_ablation.speedup >= 2``
(traced ablation at unseen values vs the per-value-recompile path), and
``algo_axis.batched_compile_programs == 1`` with
``algo_axis.speedup_cold > 1`` (one family compile vs one per algorithm).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.core import init_fed_state, make_algorithm, make_link_process, make_run_rounds
from repro.core.algorithms import algo_family, make_algorithm_spec
from repro.experiments import (
    SweepSpec,
    make_batched_run_rounds,
    make_classification_task,
    make_vmap_run_rounds,
    run_cell,
    run_cell_batch,
    seed_keys,
    stack_seed_keys,
)
from repro.experiments.grid import (
    _runner_for,
    get_task,
    get_traced_task,
    make_cell_batch,
    point_base_probs,
    seed_base_probs,
)
from repro.experiments.shard import pad_batch, resolve_batch_mesh, shard_batch
from repro.optim import paper_decay, sgd


def _cache_entries(runner) -> int:
    if not (hasattr(runner.init_batch, "_cache_size")
            and hasattr(runner.scan_batch, "_cache_size")):
        return -1
    return runner.init_batch._cache_size() + runner.scan_batch._cache_size()


def _tree_max_abs_diff(a, b) -> float:
    """Max per-leaf |a - b| over two result pytrees of equal structure,
    skipping AlgoState's zero-sized (unused) leaves."""
    return max(
        float(np.abs(np.asarray(x, np.float64)
                     - np.asarray(y, np.float64)).max())
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
        if np.asarray(x).size)


def _sequential_seed_arm(spec: SweepSpec, lr: float):
    """S per-seed sequential runs on the engine's exact protocol (shared
    dataset, engine keys, per-seed p_base) with fresh closures per seed —
    the pre-subsystem cost model. Returns ``evals [S, E]``."""
    task = get_task(spec)
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    p_base = np.asarray(seed_base_probs(spec))
    evals = []
    for i, seed in enumerate(spec.seeds):
        algo = make_algorithm(fed)                     # fresh closures: the
        opt = sgd(paper_decay(lr))                     # per-seed compile is
        link = make_link_process(p_base[i], fed)       # the cost measured
        run_rounds = make_run_rounds(task.loss_fn, opt, algo, link, fed,
                                     task.source, donate=False)
        ks = seed_keys(seed)
        st = init_fed_state(ks["state"], task.init_params(ks["params"]), fed,
                            algo, link, opt)
        ds = task.source.init(ks["ds"])
        seed_evals, t = [], 0
        while t < spec.rounds:
            chunk = min(spec.eval_every, spec.rounds - t)
            st, ds, _ = run_rounds(st, ds, ks["data"], chunk)
            t += chunk
            seed_evals.append(float(task.eval_test(st.server)))
        evals.append(seed_evals)
    return np.asarray(evals)


def _per_value_recompile_arm(spec: SweepSpec, points):
    """One PR-2 seed-axis runner per hyperparameter point — the lr baked into
    the optimizer closure (a fresh (init, scan) compile pair per point) and
    the constant-capturing task rebuilt per distinct alpha. Returns
    (evals [P, S, E], total jit cache entries)."""
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    keys = stack_seed_keys(spec.seeds)
    evals, cache_entries, tasks = [], 0, {}
    for pt in points:
        if pt["alpha"] not in tasks:        # per-alpha task rebuild: the
            tasks[pt["alpha"]] = make_classification_task(   # partition was
                data_seed=spec.data_seed,                    # a jit constant
                num_clients=spec.num_clients, dim=spec.dim,
                classes=spec.classes, hidden=spec.hidden,
                n_per_class=spec.n_per_class, n_train=spec.n_train,
                alpha=pt["alpha"], per_client=spec.per_client,
                local_steps=spec.local_steps, batch_size=spec.batch_size)
        task = tasks[pt["alpha"]]
        runner = make_vmap_run_rounds(
            task.loss_fn, sgd(paper_decay(pt["lr"])), make_algorithm(fed),
            fed, task.source,
            link_factory=lambda p: make_link_process(p, fed),
            init_params=task.init_params, num_rounds=spec.rounds,
            eval_every=spec.eval_every,
            eval_fn=task.eval_test)
        _, out = runner(keys, point_base_probs(spec, pt))
        evals.append(np.asarray(out["evals"]))
        n = _cache_entries(runner)
        cache_entries = -1 if n < 0 or cache_entries < 0 else cache_entries + n
    return np.asarray(evals), cache_entries


def _algo_axis_arm(spec: SweepSpec):
    """The fedavg-family x FedPBC grid two ways: one switch-based family
    program (1 compile) vs one statically-dispatched program per algorithm
    (4 compiles). Fresh runners on both arms (the executor cache is
    bypassed) so the compile cost each pays is its own. Returns the
    ``algo_axis`` BENCH sub-dict."""
    family = algo_family("fedavg")      # (fedpbc, fedavg, fedavg_all, known_p)
    task = get_traced_task(spec)
    fed = spec.cell_config(family[0], "bernoulli_ti")

    def _make_runner(algorithm, cfg):
        return make_batched_run_rounds(
            task.loss_fn, algorithm, cfg,
            optimizer_factory=lambda hp: sgd(paper_decay(hp["lr"])),
            link_factory=lambda p, hp: make_link_process(
                p, cfg, gamma=hp["gamma"], period=hp["period"]),
            source_factory=task.source_factory,
            init_params=task.init_params,
            num_rounds=spec.rounds, eval_every=spec.eval_every,
            eval_fn=task.eval_test, metric_keys=("loss", "num_active"))

    def timed(fn):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        return time.perf_counter() - t0, out

    # batched arm: the whole family as ONE program over the joint batch
    fam_runner = _make_runner(make_algorithm_spec(family, fed), fed)
    fam_batch = make_cell_batch(spec, fed, task, algos=family)
    B = fam_batch.batch_size
    fam_cold_s, fam_out = timed(lambda: fam_runner(fam_batch))
    fam_warm_s, _ = timed(lambda: fam_runner(fam_batch))
    fam_entries = _cache_entries(fam_runner)

    # per-algorithm arm: a fresh statically-bound runner (and compile) each
    per_cold_s = per_warm_s = 0.0
    per_entries, per_outs = 0, []
    for algo in family:
        fed_a = spec.cell_config(algo, "bernoulli_ti")
        runner_a = _make_runner(make_algorithm(fed_a), fed_a)
        batch_a = dataclasses.replace(
            make_cell_batch(spec, fed_a, task), algo_id=())
        cold, out_a = timed(lambda: runner_a(batch_a))
        warm, _ = timed(lambda: runner_a(batch_a))
        per_cold_s += cold
        per_warm_s += warm
        per_outs.append(out_a)
        n = _cache_entries(runner_a)
        per_entries = -1 if n < 0 or per_entries < 0 else per_entries + n

    ref = jax.tree.map(lambda *xs: np.concatenate(
        [np.asarray(x) for x in xs]), *per_outs)
    diff = _tree_max_abs_diff(fam_out, ref)
    if diff > 1e-5:
        raise RuntimeError(
            f"family-batched and per-algorithm trajectories diverged: {diff}")
    return {
        "family": list(family),
        "n_algos": len(family),
        "n_points": len(spec.hparam_points()),
        "n_seeds": len(spec.seeds),
        "rounds": spec.rounds,
        "n_cells": B,
        "batched_seconds_cold": round(fam_cold_s, 4),
        "batched_seconds_warm": round(fam_warm_s, 4),
        "per_algo_seconds_cold": round(per_cold_s, 4),
        "per_algo_seconds_warm": round(per_warm_s, 4),
        "batched_cold_cells_per_s": round(B / fam_cold_s, 4),
        "batched_cells_per_s": round(B / fam_warm_s, 4),
        "per_algo_cold_cells_per_s": round(B / per_cold_s, 4),
        "per_algo_cells_per_s": round(B / per_warm_s, 4),
        # (init, scan) pairs: ONE program for the whole family vs one per
        # algorithm; -1 when jit cache introspection is unavailable
        "batched_compile_programs":
            fam_entries // 2 if fam_entries >= 0 else -1,
        "per_algo_compile_programs":
            per_entries // 2 if per_entries >= 0 else -1,
        "trajectory_max_abs_diff": diff,
        "speedup_cold": round(per_cold_s / fam_cold_s, 2),
        "speedup_warm": round(per_warm_s / fam_warm_s, 2),
    }


def _device_scaling_arm(spec: SweepSpec, scaling_lrs=(0.03, 0.05, 0.1, 0.2)):
    """Warm single-device vs sharded execution of one batched cell (B =
    len(scaling_lrs) x S trajectories, padded to the device count). Returns
    the ``device_scaling`` BENCH sub-dict."""
    n_dev = len(jax.devices())
    spec = dataclasses.replace(spec, lrs=tuple(scaling_lrs))
    task = get_traced_task(spec)
    fed = spec.cell_config("fedpbc", "bernoulli_ti")
    runner = _runner_for(spec, fed, task, ("loss", "num_active"))
    batch = make_cell_batch(spec, fed, task)
    B = batch.batch_size

    def timed(fn):
        jax.block_until_ready(fn())           # compile + warm
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        return time.perf_counter() - t0, out

    single_s, ref = timed(lambda: runner(batch))
    entry = {
        "n_devices": n_dev,
        "batch": B,
        "rounds": spec.rounds,
        "padded_batch": B + (-B) % n_dev,
        "single_device_seconds": round(single_s, 4),
        "single_device_cells_per_s": round(B / single_s, 4),
    }
    if n_dev < 2:
        entry["note"] = ("single device visible; rerun under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 (CPU) or "
                         "on a multi-device backend for the sharded arm")
        return entry

    # commit the padded batch ONCE outside the timed region — the production
    # path (grid._sharded_cell_batch) memoizes this transfer per sweep, so
    # timing it per call would charge the sharded arm H2D cost the single-
    # device arm (whose batch is already device-resident) never pays
    mesh = resolve_batch_mesh()
    padded, b_real = pad_batch(batch, mesh.devices.size)
    sharded = shard_batch(padded, mesh)
    sharded_s, sh = timed(lambda: runner(sharded))
    if padded.batch_size != b_real:
        sh = jax.tree.map(lambda x: x[:b_real], sh)
    diff = _tree_max_abs_diff(ref, sh)
    # a placement change must not change a single trajectory
    if diff != 0.0:
        raise RuntimeError(
            f"sharded and single-device trajectories diverged: {diff}")
    entry.update({
        "sharded_seconds": round(sharded_s, 4),
        "sharded_cells_per_s": round(B / sharded_s, 4),
        "speedup": round(single_s / sharded_s, 2),
        "trajectory_max_abs_diff": diff,
    })
    return entry


def run(csv=True, *, rounds=100, m=32, n_seeds=8, seed0=0, out_path=None,
        ablation_lrs=(0.03, 0.05, 0.1, 0.2), ablation_alphas=(0.1, 1.0),
        ablation_seeds=4, ablation_rounds=None):
    seeds = tuple(range(seed0, seed0 + n_seeds))
    spec = SweepSpec(algorithms=("fedpbc",), schemes=("bernoulli_ti",),
                     seeds=seeds, rounds=rounds, eval_every=min(25, rounds),
                     num_clients=m)

    # --- seed axis: vmapped engine, cold (includes compile) then warm
    t0 = time.perf_counter()
    cell = run_cell(spec, "fedpbc", "bernoulli_ti", mesh=None)
    vmap_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cell = run_cell(spec, "fedpbc", "bernoulli_ti", mesh=None)
    vmap_warm_s = time.perf_counter() - t0

    # --- seed axis: sequential baseline on the SAME protocol
    t0 = time.perf_counter()
    seq_evals = _sequential_seed_arm(spec, spec.lr)
    seq_s = time.perf_counter() - t0
    traj_diff = float(np.abs(seq_evals - cell.test_acc).max())
    # same data, same keys, same p_base -> the arms must agree (bitwise at
    # equality-friendly shapes, tests/test_sweep.py; tolerance here because
    # XLA CPU may reassociate reductions by ~1 ulp at other shapes).
    # RuntimeError, not assert: the guarantee must survive `python -O`
    if traj_diff > 1e-5:
        raise RuntimeError(
            f"sequential and vmapped trajectories diverged: {traj_diff}")

    # --- hyperparameter axis: lr x alpha grid, traced vs per-value-recompile
    ab_seeds = tuple(range(seed0, seed0 + ablation_seeds))
    ab_rounds = ablation_rounds or max(rounds // 3, 20)
    ab_spec = dataclasses.replace(
        spec, seeds=ab_seeds, rounds=ab_rounds,
        eval_every=min(25, ab_rounds), lrs=tuple(ablation_lrs),
        alphas=tuple(ablation_alphas))
    points = ab_spec.hparam_points()
    n_cells = len(points) * ablation_seeds

    t0 = time.perf_counter()
    ab_cells = run_cell_batch(ab_spec, "fedpbc", "bernoulli_ti",
                              mesh=None)
    traced_cold_s = time.perf_counter() - t0
    traced_runner = _runner_for(
        ab_spec, ab_spec.cell_config("fedpbc", "bernoulli_ti"),
        get_traced_task(ab_spec), ("loss", "num_active"))
    traced_compiles = _cache_entries(traced_runner)

    # steady state: an ablation at ENTIRELY different values (same grid
    # shape) must reuse the compile — this, not the cold run, is what the
    # per-value-recompile path can never do (it recompiles per new value)
    new_spec = dataclasses.replace(
        ab_spec, lrs=tuple(lr * 1.3 for lr in ablation_lrs),
        alphas=tuple(a * 3.0 for a in ablation_alphas))
    t0 = time.perf_counter()
    run_cell_batch(new_spec, "fedpbc", "bernoulli_ti", mesh=None)
    traced_new_values_s = time.perf_counter() - t0
    traced_compiles_after = _cache_entries(traced_runner)
    if traced_compiles_after != traced_compiles:
        raise RuntimeError("new swept values triggered a recompile")

    t0 = time.perf_counter()
    baked_evals, baseline_compiles = _per_value_recompile_arm(ab_spec, points)
    baseline_s = time.perf_counter() - t0
    traced_evals = np.stack([c.test_acc for c in ab_cells])
    ab_diff = float(np.abs(baked_evals - traced_evals).max())
    if ab_diff > 1e-5:
        raise RuntimeError(
            f"traced-lr and baked-lr trajectories diverged: {ab_diff}")

    # --- algorithm axis: the fedavg family in one program vs one per algo
    algo_axis = _algo_axis_arm(
        dataclasses.replace(spec, seeds=ab_seeds, rounds=ab_rounds,
                            eval_every=min(25, ab_rounds)))

    # --- device axis: the same batched program, single-device vs sharded
    device_scaling = _device_scaling_arm(
        dataclasses.replace(spec, seeds=ab_seeds, rounds=ab_rounds,
                            eval_every=min(25, ab_rounds)),
        scaling_lrs=tuple(ablation_lrs))

    seq_cps = n_seeds / seq_s
    vmap_cps = n_seeds / vmap_warm_s
    result = {
        "bench": "sweep_throughput",
        "m": m,
        "rounds": rounds,
        "n_seeds": n_seeds,
        "local_steps": 5,
        "model": "mlp_32x64x10",
        "sequential_seconds": round(seq_s, 4),
        "vmapped_cold_seconds": round(vmap_cold_s, 4),
        "vmapped_warm_seconds": round(vmap_warm_s, 4),
        "sequential_cells_per_s": round(seq_cps, 4),
        "vmapped_cells_per_s": round(vmap_cps, 4),
        "vmapped_cold_cells_per_s": round(n_seeds / vmap_cold_s, 4),
        "speedup": round(vmap_cps / seq_cps, 2),
        "speedup_cold": round((n_seeds / vmap_cold_s) / seq_cps, 2),
        # both arms share one data protocol; their trajectories must agree
        "final_test_acc": round(float(cell.test_acc[:, -1].mean()), 4),
        "trajectory_max_abs_diff": traj_diff,
        "hparam_ablation": {
            "lrs": list(ablation_lrs),
            "alphas": list(ablation_alphas),
            "n_points": len(points),
            "n_seeds": ablation_seeds,
            "rounds": ab_rounds,
            "n_cells": n_cells,
            "traced_cold_seconds": round(traced_cold_s, 4),
            "traced_new_values_seconds": round(traced_new_values_s, 4),
            "per_value_recompile_seconds": round(baseline_s, 4),
            "traced_cells_per_s": round(n_cells / traced_new_values_s, 4),
            "traced_cold_cells_per_s": round(n_cells / traced_cold_s, 4),
            "per_value_cells_per_s": round(n_cells / baseline_s, 4),
            # jit cache entries across BOTH traced ablations (original and
            # new-values): 2 (init+scan, ONE compile each) vs 2 per grid
            # point for the per-value-recompile path; -1 if introspection is
            # unavailable
            "traced_compile_entries": traced_compiles,
            "per_value_compile_entries": baseline_compiles,
            "trajectory_max_abs_diff": ab_diff,
            "speedup": round(baseline_s / traced_new_values_s, 2),
            "speedup_first_run": round(baseline_s / traced_cold_s, 2),
        },
        "algo_axis": algo_axis,
        "device_scaling": device_scaling,
        "backend": jax.default_backend(),
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "out",
                                "sweep_throughput.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--seeds", type=int, default=8)
    a = ap.parse_args()
    run(rounds=a.rounds, m=a.clients, n_seeds=a.seeds)
