"""Sweep-throughput: vmapped multi-seed engine vs the sequential per-seed loop.

The workload is one (fedpbc, bernoulli_ti) grid cell at m=32 clients repeated
over S=8 seeds — the acceptance workload of the vectorized sweep subsystem:

- ``sequential``: S ``benchmarks.common.run_training`` calls, the
  pre-subsystem execution model. Every call builds fresh closures (data
  source, link, round step), so every seed pays its own XLA compile on top of
  its own scan dispatches and eval round-trips.
- ``vmapped``: ``repro.experiments.grid.run_cell`` — all S seeds execute as
  ONE compiled program (shared dataset, batched keys and Eq.-9 p_base, evals
  in-scan). Reported both cold (includes the one compile) and warm.

The figure of merit is cells/sec where one "cell" = one seed-run of
``rounds`` rounds. Prints a ``BENCH {...}`` JSON line and writes it to
``benchmarks/out/sweep_throughput.json``. Acceptance bar: ``speedup >= 2``
(warm vmapped vs sequential).
"""
from __future__ import annotations

import json
import os
import time

import jax

from repro.experiments import SweepSpec, run_cell

from benchmarks.common import run_training


def run(csv=True, *, rounds=100, m=32, n_seeds=8, seed0=0, out_path=None):
    seeds = tuple(range(seed0, seed0 + n_seeds))
    spec = SweepSpec(algorithms=("fedpbc",), schemes=("bernoulli_ti",),
                     seeds=seeds, rounds=rounds, eval_every=min(25, rounds),
                     num_clients=m)

    # --- vmapped engine: cold includes compile; warm re-runs the cached cell
    t0 = time.perf_counter()
    cell = run_cell(spec, "fedpbc", "bernoulli_ti")
    vmap_cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    cell = run_cell(spec, "fedpbc", "bernoulli_ti")
    vmap_warm_s = time.perf_counter() - t0

    # --- sequential baseline: one run_training per seed (recompiles per call)
    t0 = time.perf_counter()
    seq_final = []
    for sd in seeds:
        traj, _ = run_training("fedpbc", "bernoulli_ti", rounds=rounds, m=m,
                               seed=sd)
        seq_final.append(traj[-1][1])
    seq_s = time.perf_counter() - t0

    seq_cps = n_seeds / seq_s
    vmap_cps = n_seeds / vmap_warm_s
    result = {
        "bench": "sweep_throughput",
        "m": m,
        "rounds": rounds,
        "n_seeds": n_seeds,
        "local_steps": 5,
        "model": "mlp_32x64x10",
        "sequential_seconds": round(seq_s, 4),
        "vmapped_cold_seconds": round(vmap_cold_s, 4),
        "vmapped_warm_seconds": round(vmap_warm_s, 4),
        "sequential_cells_per_s": round(seq_cps, 4),
        "vmapped_cells_per_s": round(vmap_cps, 4),
        "vmapped_cold_cells_per_s": round(n_seeds / vmap_cold_s, 4),
        "speedup": round(vmap_cps / seq_cps, 2),
        "speedup_cold": round((n_seeds / vmap_cold_s) / seq_cps, 2),
        # NOT directly comparable: the engine shares one data_seed=0 dataset
        # across seeds (the sweep protocol), run_training rebuilds the
        # dataset from each seed — these are plausibility checks, not an
        # equivalence test (tests/test_sweep.py does bitwise equivalence)
        "final_test_acc_vmapped_shared_data": round(
            float(cell.test_acc[:, -1].mean()), 4),
        "final_test_acc_sequential_per_seed_data": round(
            sum(seq_final) / n_seeds, 4),
        "backend": jax.default_backend(),
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path is None:
        out_path = os.path.join(os.path.dirname(__file__), "out",
                                "sweep_throughput.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)
    return result


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--seeds", type=int, default=8)
    a = ap.parse_args()
    run(rounds=a.rounds, m=a.clients, n_seeds=a.seeds)
