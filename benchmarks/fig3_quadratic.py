"""Fig. 3: quadratic counterexample — ||x_PS - x*|| over rounds for FedPBC vs
FedAvg under (p0, p1) split-population Bernoulli links, 3 seeds.

Paper setup: m=100, d=100, s=100, 2500 rounds, eta=1e-4. Default here is a
CPU-scaled version (m=50, s=20, 800 rounds, eta=5e-4); pass --paper-scale for
the full thing."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FederationConfig
from repro.core import init_fed_state, make_algorithm, make_link_process, make_run_rounds
from repro.data import fixed_source
from repro.optim import sgd


def run_one(algo_name, p0, p1, *, m, d, s, rounds, eta, seed):
    key = jax.random.PRNGKey(seed)
    u = (jnp.arange(m) / (10.0 * m))[:, None] + 0.1 * jax.random.normal(key, (m, d))
    x_star = u.mean(0)
    p = jnp.where(jnp.arange(m) < m // 2, p0, p1)
    fed = FederationConfig(algorithm=algo_name, num_clients=m, local_steps=s)
    algo = make_algorithm(fed)
    link = make_link_process(p, fed)
    loss = lambda params, batch: 0.5 * jnp.sum((params["x"] - batch["u"]) ** 2)
    opt = sgd(eta)
    source = fixed_source({"u": jnp.broadcast_to(u[:, None], (m, s, d))})
    run_rounds = make_run_rounds(loss, opt, algo, link, fed, source)
    st = init_fed_state(jax.random.PRNGKey(seed + 1), {"x": jnp.zeros(d)},
                        fed, algo, link, opt)
    ds_state = source.init(jax.random.PRNGKey(seed + 2))
    data_key = jax.random.PRNGKey(seed + 3)
    # 20 measurement points = 20 scan chunks instead of `rounds` dispatches
    chunk = max(rounds // 20, 1)
    dists, t = [], 0
    while t < rounds:
        step = min(chunk, rounds - t)
        st, ds_state, _ = run_rounds(st, ds_state, data_key, step)
        t += step
        dists.append((t, float(jnp.linalg.norm(st.server["x"] - x_star))))
    return dists


def run(csv=True, *, m=50, d=50, s=20, rounds=800, eta=5e-4, seeds=(0, 1, 2)):
    if csv:
        print("fig3_quadratic,algo,p0,p1,round,dist_mean,dist_std")
    out = {}
    for (p0, p1) in [(0.5, 0.5), (0.9, 0.1), (0.5, 0.1)]:
        for algo in ("fedpbc", "fedavg"):
            per_seed = [run_one(algo, p0, p1, m=m, d=d, s=s, rounds=rounds,
                                eta=eta, seed=sd) for sd in seeds]
            rounds_axis = [r for r, _ in per_seed[0]]
            vals = np.array([[v for _, v in tr] for tr in per_seed])
            out[(algo, p0, p1)] = (rounds_axis, vals.mean(0), vals.std(0))
            if csv:
                for i, r in enumerate(rounds_axis):
                    print(f"fig3_quadratic,{algo},{p0},{p1},{r},"
                          f"{vals.mean(0)[i]:.5f},{vals.std(0)[i]:.5f}")
    # the paper's qualitative claim: FedPBC's final error under p0!=p1 is
    # close to the p0==p1 level; FedAvg's is far larger
    final = {k: v[1][-1] for k, v in out.items()}
    print(f"# fedpbc p!=p final {final[('fedpbc',0.9,0.1)]:.4f} vs "
          f"fedavg {final[('fedavg',0.9,0.1)]:.4f} "
          f"(uniform-p fedavg {final[('fedavg',0.5,0.5)]:.4f})")
    return final


if __name__ == "__main__":
    run()
