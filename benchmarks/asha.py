"""Adaptive search (successive halving) vs the exhaustive grid:
time-to-target-accuracy on the resumable scan-segment runner.

Three arms over the same FedPBC / Bernoulli-time-varying cell:

1. **baseline** — ``table2_rounds_to_target`` run on the same protocol; its
   machine-readable JSON gives the absolute accuracy targets (we use the
   q75 target: 3/4 of the single-point run's best accuracy).
2. **grid** — the exhaustive lr grid through ``run_cell_batch``: every
   point burns the full ``rounds`` budget, so its device cost is fixed at
   ``points * seeds * rounds`` trajectory-rounds.
3. **asha** — ``run_search`` over the SAME lr pool with rung-sized
   segments: losers are pruned at each rung on in-scan evals, survivors
   are elastically re-packed into full batches, and the per-wave
   ``wave_log`` gives the honest post-hoc device-rounds-to-target
   (duplicate-padding slots and all seeds counted).

Enforced bars (RuntimeError on regression):

- ASHA's total device rounds < the exhaustive grid's (the perf claim), at
  equal final-answer quality: ASHA's best accuracy within 0.02 of the
  grid's best and above the table-2 q75 target  [full mode only];
- compile pin: the ENTIRE search — every rung, every survivor re-pack,
  the resume probe — holds ONE init and ONE scan cache entry on the
  segment runner;
- rung-resume bitwise bar: k chained ``rung_rounds`` segments reproduce
  one uninterrupted ``k * rung_rounds`` program bit-for-bit (evals, loss).

Prints a ``BENCH {...}`` JSON line and writes ``benchmarks/out/asha.json``.
``--smoke`` shrinks everything for CI (structural bars only).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.analysis.sanitize import cache_size
from repro.experiments import SweepSpec, run_cell_batch
from repro.experiments.grid import (
    _runner_for,
    get_traced_task,
    make_cell_batch,
    segment_runner_for,
)
from repro.experiments.search import SearchSpec, run_search

from benchmarks import table2_rounds_to_target

OUT_PATH = os.path.join(os.path.dirname(__file__), "out", "asha.json")

ALGO, SCHEME = "fedpbc", "bernoulli_tv"
LRS = (0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.3, 0.5)


def _final_acc(test_acc: np.ndarray) -> float:
    """Seed-mean test accuracy over the last (up to) 3 evals — the same
    window ``CellResult.summary`` / the search's persisted summary use."""
    w = min(3, test_acc.shape[1])
    return float(test_acc[:, -w:].mean(axis=1).mean())


def _resume_probe(base: SweepSpec, lrs, seg: int, segments: int = 2):
    """Bit-for-bit bar: chain ``segments`` rung-sized scans on the segment
    runner and compare against ONE uninterrupted ``segments * seg``-round
    program of the historical runner, same batch. The probe batch has the
    search's exact width, so it rides the already-compiled segment entry."""
    spec = dataclasses.replace(base, lrs=tuple(lrs),
                               rounds=segments * seg, eval_every=seg)
    task = get_traced_task(spec)
    fed = spec.cell_config(ALGO, SCHEME)
    batch = make_cell_batch(spec, fed, task)
    rseg = segment_runner_for(spec, ALGO, SCHEME, segment_rounds=seg)
    carry, evals, losses = rseg.init(batch), [], []
    for _ in range(segments):
        carry, out = rseg.step(carry, batch)
        evals.append(np.asarray(out["evals"]))
        losses.append(np.asarray(out["metrics"]["loss"]))
    seg_evals = np.concatenate(evals, axis=1)
    seg_loss = np.concatenate(losses, axis=1)
    full = _runner_for(spec, fed, task, ("loss", "num_active"))
    _, out = full(batch)
    d_evals = np.abs(seg_evals - np.asarray(out["evals"])).max()
    d_loss = np.abs(seg_loss - np.asarray(out["metrics"]["loss"])).max()
    return float(max(d_evals, d_loss)), rseg


def run(csv=True, *, rounds=64, m=16, seeds=(0, 1), lrs=LRS,
        rung_rounds=8, eta=2, batch_points=4, smoke=False,
        out_path=OUT_PATH, store=None):
    if smoke:
        rounds, rung_rounds, m = 8, 4, 8
        seeds, lrs, batch_points = (0,), (0.05, 0.1, 0.2, 0.4), 2
        out_path = None
    # the budget cap must be a whole number of rungs; snap down (>= 2 rungs)
    rounds = max(rounds // rung_rounds, 2) * rung_rounds
    base = SweepSpec(algorithms=(ALGO,), schemes=(SCHEME,), seeds=seeds,
                     rounds=rounds, eval_every=rung_rounds, num_clients=m)
    S = len(seeds)

    # arm 1: the table-2 single-point baseline on the same protocol fixes
    # the absolute accuracy targets (machine-readable JSON)
    baseline = table2_rounds_to_target.run(
        csv=False, rounds=rounds, m=m, algos=(ALGO,), seed=seeds[0],
        out_path=None if smoke else table2_rounds_to_target.OUT_PATH)
    target = baseline["targets"][2]             # q75

    # arm 2: exhaustive grid — every lr runs the full budget (mesh=None:
    # one-device path, deterministic under CI's forced host-device count)
    grid_spec = dataclasses.replace(base, lrs=tuple(lrs))
    grid_cells = run_cell_batch(grid_spec, ALGO, SCHEME, mesh=None)
    grid_total = len(lrs) * S * rounds
    grid_best = max(_final_acc(c.test_acc) for c in grid_cells)
    # post-hoc: first eval round at which the best cell's seed-mean curve
    # reached the target (the grid still had to RUN everything to know)
    grid_first = None
    for c in grid_cells:
        curve = c.test_acc.mean(axis=0)
        for r, a in zip(c.eval_rounds, curve):
            if a >= target - 1e-9:
                grid_first = min(grid_first or r, r)
                break

    # arm 3: successive halving over the SAME lr pool
    search = SearchSpec(base=base, rung_rounds=rung_rounds, eta=eta,
                        batch_points=batch_points,
                        points=tuple({"lr": v} for v in lrs))
    outcome = run_search(search, store=store, suite="asha")
    asha_best = outcome.best.last_eval
    asha_total = outcome.total_device_rounds
    asha_to_target = outcome.device_rounds_to(target)

    # structural bars on the very same runner the search used
    resume_diff, rseg = _resume_probe(base, lrs[:search.width], rung_rounds)
    entries = {"init": cache_size(rseg.init_batch),
               "scan": cache_size(rseg.scan_batch)}

    result = {
        "bench": "asha_vs_grid",
        "smoke": bool(smoke),
        "protocol": {"algo": ALGO, "scheme": SCHEME, "m": m,
                     "rounds": rounds, "seeds": list(seeds),
                     "rung_rounds": rung_rounds, "eta": eta,
                     "batch_points": batch_points, "lrs": list(lrs)},
        "baseline": {"best_acc": baseline["best_acc"],
                     "targets": baseline["targets"],
                     "target_q75": target},
        "grid": {"device_rounds": grid_total, "best_acc": grid_best,
                 "first_round_at_target": grid_first},
        "asha": {"device_rounds": asha_total, "best_acc": asha_best,
                 "device_rounds_to_target": asha_to_target,
                 "waves": outcome.waves,
                 "wave_log": outcome.wave_log,
                 "candidates": len(outcome.candidates),
                 "statuses": {s: sum(c.status == s
                                     for c in outcome.candidates)
                              for s in ("pruned", "finished", "stopped")}},
        "speedup": {"device_rounds_ratio": grid_total / max(asha_total, 1)},
        "compile_entries": entries,
        "resume_max_abs_diff": resume_diff,
    }
    print("BENCH " + json.dumps(result), flush=True)
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2, sort_keys=True)
    if csv:
        print("asha,arm,device_rounds,best_acc,rounds_to_target")
        print(f"asha,grid,{grid_total},{grid_best:.4f},"
              f"{grid_first if grid_first is not None else -1}")
        print(f"asha,asha,{asha_total},{asha_best:.4f},"
              f"{asha_to_target if asha_to_target is not None else -1}",
              flush=True)

    # -- enforced bars ----------------------------------------------------
    if asha_total >= grid_total:
        raise RuntimeError(
            f"ASHA spent {asha_total} device rounds, the exhaustive grid "
            f"{grid_total}: early pruning saved nothing")
    if entries["init"] not in (None, 1) or entries["scan"] not in (None, 1):
        raise RuntimeError(
            f"segment runner compiled more than once across rungs, "
            f"re-batches and the resume probe: {entries} (elastic re-pack "
            f"must be structure-stable)")
    if resume_diff != 0.0:
        raise RuntimeError(
            f"chained rung segments diverged from the uninterrupted scan: "
            f"max|d|={resume_diff} (resume must be bit-for-bit)")
    if not smoke:
        if asha_best < target - 1e-9:
            raise RuntimeError(
                f"ASHA best accuracy {asha_best:.4f} missed the table-2 "
                f"q75 target {target:.4f}")
        if asha_best < grid_best - 0.02:
            raise RuntimeError(
                f"ASHA final-answer quality {asha_best:.4f} fell more than "
                f"0.02 below the exhaustive grid's {grid_best:.4f}")
    return result


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config (structural bars only)")
    ap.add_argument("--rounds", type=int, default=64)
    args = ap.parse_args()
    run(rounds=args.rounds, smoke=args.smoke)
